"""Multi-edge fleet serving: cross-tenant batched verify on one shared
cloud engine.

Covers the fleet engine's four load-bearing guarantees:

* **per-tenant stream isolation** — a tenant's greedy stream is
  bit-identical whether it shares the fleet batch with other tenants
  (at other cuts / draft lengths, over one shared ``_CutBank`` and page
  pool) or runs alone on a solo ``CollaborativeServingEngine``.
  Checked losslessly (``a_bits=None``) as a hypothesis property over
  random cut/k/prompt draws, and in the full INT8 deployment mode
  (per-slot Eq.(1) lattices: ``QuantCtx(act_axis=0)`` + per-slot KV
  scales are what make the INT8 case hold);
* **shared weight bank** — co-cut tenants share one runtime and every
  runtime's weights come out of the single prequantized ``_CutBank``
  (pointer swap, no per-tenant copies);
* **weighted-fair sharing** — quotas bound a tenant's page footprint,
  preemption under pool pressure picks the over-share tenant, and both
  tenants' streams still complete exactly;
* **fault isolation** — seeded per-tenant fault schedules (drops,
  corruption, a full outage) slow only the faulted tenant's simulated
  clock; a calm tenant co-batched with the storm keeps committing and
  pays zero fault time.  This file is CI's fleet chaos step.
"""
import jax
import numpy as np
import pytest

from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         FleetServingEngine, Request, TenantSpec)
from repro.serve.policy import FleetFairness

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="fleet-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8
LOSSLESS_FP = dict(a_bits=None, edge_int8=False, cloud_int8=False,
                   page_size=PAGE, max_len=64)
FAST = Channel.from_kbps(2000, rtt_ms=20)
SLOW = Channel.from_kbps(500, rtt_ms=60)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


def _reqs(n, seed=0, gap=0.0, **kw):
    return [Request(uid=i, prompt=p, max_new_tokens=8, arrival_s=i * gap,
                    **kw)
            for i, p in enumerate(_prompts([6] * n, seed=seed))]


# ---------------------------------------------------------------------------
# Stream isolation: fleet co-batching never changes a tenant's tokens
# ---------------------------------------------------------------------------


def _identity_example(params, cut_a, cut_b, k_a, k_b, seed):
    """One draw of the property: tenants a/b at (cut, k) over a shared
    bank must stream bit-identically to solo engines."""
    rng = np.random.RandomState(seed)
    prompts = {n: [rng.randint(0, CFG.vocab, int(l)).astype(np.int32)
                   for l in rng.randint(3, 12, 3)]
               for n in ("a", "b")}
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("a", FAST, cut_layer=cut_a, spec_k=k_a),
         TenantSpec("b", SLOW, cut_layer=cut_b, spec_k=k_b)],
        max_batch=4, **LOSSLESS_FP)
    got = fleet.generate(prompts, max_new_tokens=10)
    for name, cut, k, ch in [("a", cut_a, k_a, FAST),
                             ("b", cut_b, k_b, SLOW)]:
        solo = CollaborativeServingEngine(
            params, CFG, cut_layer=cut, spec_k=k, channel=ch,
            max_batch=2, **LOSSLESS_FP)
        assert got[name] == solo.generate(prompts[name], max_new_tokens=10)


# property test, guarded like the rest of the tier-1 suite; without
# hypothesis the same property runs over a fixed grid of draws so the
# guarantee is still exercised, just not fuzzed
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=5, deadline=None)
    @given(cut_a=st.sampled_from([0, 1, 2]),
           cut_b=st.sampled_from([0, 1, 2]),
           k_a=st.sampled_from([1, 2, 4]),
           k_b=st.sampled_from([1, 2, 4]),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_fleet_lossless_bit_identity_property(params, cut_a, cut_b,
                                                  k_a, k_b, seed):
        """Hypothesis property: two tenants at random (cut, k) over one
        shared bank/pool — interleaved fleet streams are bit-identical
        (``a_bits=None``) to each tenant served alone."""
        _identity_example(params, cut_a, cut_b, k_a, k_b, seed)
else:
    @pytest.mark.parametrize("cut_a,cut_b,k_a,k_b,seed",
                             [(0, 1, 1, 4, 11), (2, 2, 4, 4, 23),
                              (1, 0, 2, 1, 47)])
    def test_fleet_lossless_bit_identity_property(params, cut_a, cut_b,
                                                  k_a, k_b, seed):
        _identity_example(params, cut_a, cut_b, k_a, k_b, seed)


def test_fleet_int8_bit_identity(params):
    """The deployed INT8 mode holds the same isolation: per-slot Eq.(1)
    activation lattices (act_axis=0) and per-slot KV scales mean a
    tenant's stream doesn't depend on who shares the batch — even at a
    different max_batch than the solo reference."""
    prompts = {n: _prompts([7, 5, 9], seed=3 + i)
               for i, n in enumerate(("a", "b"))}
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("a", FAST, cut_layer=0, spec_k=1),
         TenantSpec("b", SLOW, cut_layer=1, spec_k=4)],
        max_batch=4, max_len=64, page_size=PAGE)
    got = fleet.generate(prompts, max_new_tokens=12)
    for name, cut, k, ch in [("a", 0, 1, FAST), ("b", 1, 4, SLOW)]:
        solo = CollaborativeServingEngine(
            params, CFG, cut_layer=cut, spec_k=k, channel=ch,
            max_batch=2, max_len=64, page_size=PAGE)
        assert got[name] == solo.generate(prompts[name], max_new_tokens=12)


def test_fleet_shares_one_cut_bank(params):
    """Co-cut tenants share one ``_CutRuntime``; every runtime's blocks
    are the bank's cached slices (pointer identity — no weight copies)."""
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("a", FAST, cut_layer=1, spec_k=2),
         TenantSpec("b", SLOW, cut_layer=1, spec_k=2),
         TenantSpec("c", SLOW, cut_layer=2, spec_k=1)],
        max_batch=4, max_len=64, page_size=PAGE)
    fleet.generate({n: _prompts([6], seed=i)
                    for i, n in enumerate(("a", "b", "c"))},
                   max_new_tokens=4)
    assert fleet._runtime(1) is fleet._runtime(1)      # one runtime per cut
    for cut in (1, 2):
        rt = fleet._runtime(cut)
        edge, cloud, draft = fleet._bank.get(cut)
        assert rt.edge_blocks is edge and rt.cloud_blocks is cloud
        assert rt.draft_blocks is draft
    # both live runtimes index the one shared page pool (shape
    # [L, num_pages, page, n_kv, hd] — pool geometry is the pool's)
    assert fleet._runtime(1)._edge_cache["k_pages"].shape[1] \
        == fleet._runtime(2)._edge_cache["k_pages"].shape[1] \
        == fleet._pool.allocator.num_pages


# ---------------------------------------------------------------------------
# Weighted-fair sharing: quotas, preemption, pool gauges
# ---------------------------------------------------------------------------


def test_fleet_fairness_keys():
    ff = FleetFairness({"a": 3.0, "b": 1.0}, quotas={"a": None, "b": 4})
    ff.charge("a", 9)
    ff.charge("b", 3)
    assert ff.vservice["a"] == pytest.approx(3.0)      # weighted: 9 / 3
    assert ff.vservice["b"] == pytest.approx(3.0)      # 3 / 1
    ra = Request(uid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    rb = Request(uid=1, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    ra.tenant, rb.tenant = "a", "b"
    ra._seq, rb._seq = 0, 1
    ff.charge("b", 1)                                  # b now behind... ahead
    assert ff.admission_key(ra) < ff.admission_key(rb)
    assert not ff.over_quota("a", 100) and ff.over_quota("b", 5)
    assert ff.fair_pages("a", 16) == pytest.approx(12.0)


def test_fleet_page_quota_bounds_footprint(params):
    """A quota'd tenant's page footprint never exceeds ``max_pages``;
    its stream still completes and the unquota'd tenant is unaffected."""
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("hog", FAST, cut_layer=1, spec_k=1, max_pages=2),
         TenantSpec("meek", SLOW, cut_layer=1, spec_k=1)],
        max_batch=4, max_len=64, page_size=PAGE)
    peaks = {"hog": 0, "meek": 0}
    orig = fleet._pool.admit

    def admit(slots, plens, max_news, padded_len, owner=None):
        out = orig(slots, plens, max_news, padded_len, owner=owner)
        for t in peaks:
            peaks[t] = max(peaks[t], fleet._pool.owner_pages(t))
        return out

    fleet._pool.admit = admit
    out = fleet.generate({"hog": _prompts([6] * 4, seed=0),
                          "meek": _prompts([6] * 2, seed=1)},
                         max_new_tokens=8)
    # 6-token prompt + 8 new = 2 pages/request: the quota serializes the
    # hog's 4 requests (one live at a time) while the unquota'd tenant
    # keeps both of its requests resident
    assert peaks["hog"] <= 2 < peaks["meek"]
    assert all(len(t) == 8 for t in out["hog"] + out["meek"])


def test_fleet_cross_tenant_preemption(params):
    """Under pool pressure the over-share tenant is preempted (and
    resumed); the light tenant is never the victim and both finish."""
    # 8 usable pages; 4 live slots x 3 pages each (6 + 18 tokens) wants
    # 12 -> a page fault mid-decode must preempt, and the victim must be
    # a slot of the over-fair-share tenant
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("hog", FAST, cut_layer=1, spec_k=1),
         TenantSpec("meek", SLOW, cut_layer=1, spec_k=1)],
        max_batch=4, max_len=64, page_size=PAGE,
        num_pages=9, demand_paged=True)
    out = fleet.generate({"hog": _prompts([6] * 3, seed=0),
                          "meek": _prompts([6], seed=1)},
                         max_new_tokens=18)
    assert fleet.tenant("hog").stats.preemptions >= 1
    assert fleet.tenant("meek").stats.preemptions == 0
    assert all(len(t) == 18 for t in out["hog"] + out["meek"])


def test_stats_expose_pool_gauges(params):
    """Satellite: ``ServeStats`` carries the shared pool's free-page and
    utilization gauges, per tenant and on the fleet aggregate."""
    fleet = FleetServingEngine(
        params, CFG, [TenantSpec("a", FAST, cut_layer=1, spec_k=2)],
        max_batch=2, max_len=64, page_size=PAGE)
    fleet.generate({"a": _prompts([6, 6], seed=0)}, max_new_tokens=8)
    st = fleet.tenant("a").stats
    assert st.pool_utilization_peak > 0.0
    # the gauges are sampled while slots are live: fewer pages free than
    # the drained pool shows after the run
    assert 0 <= st.pool_free_pages < fleet._pool.free_pages() \
        <= fleet._pool.allocator.num_pages - 1
    assert 0.0 < st.pool_utilization <= st.pool_utilization_peak <= 1.0
    assert fleet.stats.pool_utilization_peak == st.pool_utilization_peak


# ---------------------------------------------------------------------------
# Fleet chaos: seeded per-tenant fault schedules (CI's chaos step)
# ---------------------------------------------------------------------------


def test_fleet_chaos_outage_isolation(params):
    """One tenant rides a storm (drops + corruption + a long outage)
    while a calm tenant shares the batch: both streams complete, the
    storm pays its fault time on its own clock, and the calm tenant's
    clock/faults show none of it."""
    storm = FaultyChannel(Channel.from_kbps(500, rtt_ms=40), seed=7,
                          drop_p=0.2, corrupt_p=0.1,
                          outages=[(0.05, 0.8)], rto_s=0.1)
    calm = FaultyChannel(Channel.from_kbps(2000, rtt_ms=20), seed=11)
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("storm", storm, cut_layer=1, spec_k=2),
         TenantSpec("calm", calm, cut_layer=1, spec_k=2)],
        max_batch=4, max_len=64, page_size=PAGE)
    out = fleet.generate({"storm": _prompts([6, 6], seed=0),
                          "calm": _prompts([6, 6], seed=1)},
                         max_new_tokens=8)
    assert all(len(t) == 8 for t in out["storm"] + out["calm"])
    assert sum(storm.faults.values()) > 0
    assert sum(calm.faults.values()) == 0
    # the outage shows up only on the storm tenant's simulated clock
    assert storm.clock_s > 0.8 > calm.clock_s
    # isolation is exact: the calm stream matches a storm-free solo run
    solo = CollaborativeServingEngine(
        params, CFG, cut_layer=1, spec_k=2,
        channel=Channel.from_kbps(2000, rtt_ms=20),
        max_batch=2, max_len=64, page_size=PAGE)
    assert out["calm"] == solo.generate(_prompts([6, 6], seed=1),
                                        max_new_tokens=8)


def test_fleet_chaos_every_tenant_faulted(params):
    """All four tenants under distinct seeded fault schedules keep
    committing; per-tenant stats stay separated (each tenant's wire
    bytes and waits live on its own ``ServeStats``)."""
    chans = {f"e{i}": FaultyChannel(Channel.from_kbps(1000, rtt_ms=30),
                                    seed=i, drop_p=0.1 * (i % 3),
                                    stall_p=0.05 * i, stall_s=0.05)
             for i in range(4)}
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec(n, ch, cut_layer=1, spec_k=2)
         for n, ch in chans.items()],
        max_batch=8, max_len=64, page_size=PAGE)
    out = fleet.generate({n: _prompts([6, 6], seed=i)
                          for i, n in enumerate(chans)}, max_new_tokens=8)
    agg = fleet.stats
    for n, ch in chans.items():
        st = fleet.tenant(n).stats
        assert all(len(t) == 8 for t in out[n])
        # 2 requests x 7 decode-committed tokens (the 8th of each stream
        # is the prefill's) — charged to this tenant's stats, nobody
        # else's
        assert st.decode_tokens == 14
        assert 0 < st.transmitted_bytes < agg.transmitted_bytes
    assert agg.decode_tokens == 4 * 14
