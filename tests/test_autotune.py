"""Algorithm 1 behaviour tests: the auto-tuner's decisions must move in the
directions the paper demonstrates (Table 3 / Fig 3)."""
import pytest

from repro.core.autotune import AutoTuner, auto_tune
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS, tpu_v5e_pod)
from repro.core.graph import LayerGraph


def alexnet_like() -> LayerGraph:
    """Conv-heavy front, FC-heavy tail — AlexNet's shape, tiny numbers.
    Output blobs SHRINK monotonically, which is what makes late cuts win
    at low bandwidth (paper Fig 3: conv5 is best/fastest for AlexNet)."""
    g = LayerGraph("alexnet-like")
    g.add("input", "input", [], (1, 3, 227, 227))
    shapes = [(1, 96, 55, 55), (1, 256, 27, 27), (1, 384, 13, 13),
              (1, 384, 13, 13), (1, 256, 6, 6)]
    prev = "input"
    for i, s in enumerate(shapes, 1):
        prev = g.add(f"conv{i}", "conv", [prev], s, flops=2e8,
                     param_elems=int(4e5 * i))
        prev = g.add(f"relu{i}", "relu", [prev], s)
    for i, width in enumerate((4096, 4096, 1000), 6):
        prev = g.add(f"fc{i}", "dense", [prev], (1, width), flops=6e7,
                     param_elems=int(2e7) if i < 8 else int(4e6))
    g.validate()
    return g


EDGE, CLOUD = EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS


def test_low_bandwidth_prefers_late_cut_high_prefers_cloud():
    g = alexnet_like()
    tuner = AutoTuner(g, EDGE, CLOUD)
    slow = Channel.from_kbps(100)           # paper's wireless regime
    fast = Channel(bandwidth_bytes_per_s=1e9)   # datacenter-grade link
    best_slow, _ = tuner.tune(slow)
    best_fast, _ = tuner.tune(fast)
    # slow link: push compute to the edge until the blob is small
    assert best_slow.point in ("conv5", "fc6", "fc7", "fc8")
    # fast link: shipping the raw input is cheap; cloud does everything
    assert best_fast.point == "input"


def test_speedup_vs_cloud_only_positive_at_low_bandwidth():
    g = alexnet_like()
    tuner = AutoTuner(g, EDGE, CLOUD)
    sp = tuner.speedup_vs_cloud_only(Channel.from_kbps(250))
    assert sp > 1.0                          # paper Table 3: 1.7x for AlexNet


def test_best_is_argmin_of_reported_set():
    g = alexnet_like()
    ch = Channel.from_kbps(250)
    best, perfs = auto_tune(g, EDGE, CLOUD, ch)
    assert best.total_s == min(p.total_s for p in perfs)
    assert len(perfs) >= 5                   # input + conv1..5-ish + fcs


def test_storage_reduction_monotone_decreasing_along_cuts():
    """Later cut → more weights downloaded to edge → less reduction."""
    g = alexnet_like()
    tuner = AutoTuner(g, EDGE, CLOUD)
    _, perfs = tuner.tune(Channel.from_kbps(250))
    reductions = [p.storage_reduction for p in perfs]
    assert all(x >= y - 1e-9 for x, y in zip(reductions, reductions[1:]))
    # INT8 model is 4x smaller: cut-at-last still shows 75% reduction
    assert reductions[-1] == pytest.approx(0.75, abs=1e-6)


def test_measured_profile_overrides_analytic_model():
    g = alexnet_like()
    ch = Channel.from_kbps(250)
    # force the analytic winner to look terrible on the measured edge
    base, _ = AutoTuner(g, EDGE, CLOUD).tune(ch)
    prof = {base.point: 1e3}                 # 1000 s measured
    tuned, _ = AutoTuner(g, EDGE, CLOUD, edge_profile=prof).tune(ch)
    assert tuned.point != base.point


def test_constraint_filters_feasible_set():
    g = alexnet_like()
    ch = Channel.from_kbps(250)
    tuner = AutoTuner(g, EDGE, CLOUD)
    best, _ = tuner.tune(ch, constraints=lambda p: p.edge_model_bytes < 1e6)
    assert best.edge_model_bytes < 1e6


def test_loop_steps_multiplies_transmission():
    """Diffusion samplers cross the wire once per step (DESIGN.md §4)."""
    g = alexnet_like()
    ch = Channel.from_kbps(250)
    t1 = AutoTuner(g, EDGE, CLOUD, loop_steps=1)
    t50 = AutoTuner(g, EDGE, CLOUD, loop_steps=50)
    p1 = t1.predict_performance(t1.candidates[2], ch)
    p50 = t50.predict_performance(t50.candidates[2], ch)
    assert p50.upload_time_s == pytest.approx(50 * p1.upload_time_s)


def test_tpu_pod_cloud_reduces_cloud_time():
    g = alexnet_like()
    ch = Channel.from_kbps(250)
    small = AutoTuner(g, EDGE, tpu_v5e_pod(1))
    big = AutoTuner(g, EDGE, tpu_v5e_pod(256))
    c = small.candidates[1]
    assert (big.predict_performance(c, ch).cloud_time_s
            <= small.predict_performance(c, ch).cloud_time_s)
