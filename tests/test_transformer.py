"""LM model-family tests on reduced configs (CPU smoke scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (LMConfig, MoESpec, decode_step, forward,
                                      init_cache, init_lm, lm_loss,
                                      make_graph, make_segments, prefill)

jax.config.update("jax_platform_name", "cpu")

DENSE = LMConfig(name="tiny-dense", n_layers=3, d_model=32, n_heads=4,
                 n_kv=2, d_ff=64, vocab=128, max_seq=64, remat=False)
MOE = LMConfig(name="tiny-moe", n_layers=2, d_model=32, n_heads=4, n_kv=4,
               d_ff=48, vocab=128, moe=MoESpec(n_experts=4, top_k=2),
               max_seq=64, remat=False)


@pytest.fixture(scope="module")
def dense_params():
    return init_lm(jax.random.PRNGKey(0), DENSE)


@pytest.fixture(scope="module")
def moe_params():
    return init_lm(jax.random.PRNGKey(1), MOE)


def _tokens(b, s, vocab, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).randint(0, vocab, (b, s)), jnp.int32)


@pytest.mark.parametrize("cfg,pfix", [(DENSE, "dense_params"),
                                      (MOE, "moe_params")])
def test_forward_shapes_and_finite(cfg, pfix, request):
    params = request.getfixturevalue(pfix)
    toks = _tokens(2, 16, cfg.vocab)
    logits, aux = forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


def test_loss_decreases_under_sgd(dense_params):
    cfg = DENSE
    toks = _tokens(2, 16, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    loss_g = jax.jit(jax.value_and_grad(lambda p: lm_loss(p, batch, cfg)))
    p = dense_params
    l0, g = loss_g(p)
    for _ in range(5):
        l, g = loss_g(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.5 * b, p, g)
    l_end, _ = loss_g(p)
    assert float(l_end) < float(l0)


def test_causality(dense_params):
    """Changing a future token must not affect earlier logits."""
    cfg = DENSE
    t1 = _tokens(1, 12, cfg.vocab, seed=3)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
    l1, _ = forward(dense_params, t1, cfg)
    l2, _ = forward(dense_params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


@pytest.mark.parametrize("cfg,pfix", [(DENSE, "dense_params"),
                                      (MOE, "moe_params")])
def test_prefill_then_decode_matches_forward(cfg, pfix, request):
    """KV-cache serving path must agree with the monolithic forward."""
    params = request.getfixturevalue(pfix)
    b, s = 2, 10
    toks = _tokens(b, s + 1, cfg.vocab, seed=5)
    full_logits, _ = forward(params, toks, cfg)

    cache = init_cache(cfg, b, max_len=32)
    last, cache = prefill(params, toks[:, :s], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, s - 1]),
                               rtol=2e-4, atol=2e-4)
    step_logits, cache = decode_step(params, toks[:, s], cache,
                                     jnp.int32(s), cfg)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_moe_routes_to_multiple_experts(moe_params):
    from repro.models import layers as L
    cfg = MOE
    x = jnp.asarray(np.random.RandomState(7).randn(2, 16, 32), jnp.float32)
    bp = jax.tree_util.tree_map(lambda v: v[0], moe_params["blocks"])
    y, aux = L.moe(bp["moe"], x, top_k=cfg.moe.top_k)
    assert y.shape == x.shape
    assert float(aux) > 0.5          # balanced routing ⇒ aux ≈ 1
    # permutation of tokens only permutes outputs (router is per-token)
    # note: capacity assignment is order-dependent, so use high capacity
    y2, _ = L.moe(bp["moe"], x[:, ::-1], top_k=cfg.moe.top_k,
                  capacity_factor=4.0)
    y1, _ = L.moe(bp["moe"], x, top_k=cfg.moe.top_k, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y1[:, ::-1]), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_graph_block_boundaries_are_candidates():
    from repro.core.partition import candidate_partition_points
    g = make_graph(DENSE, batch=1, seq=16)
    cands = {c.name for c in candidate_partition_points(g)}
    assert "embed" in cands and "lm_head" in cands
    for i in range(DENSE.n_layers):
        assert f"blk{i}/ffn" in cands       # block boundary (fused add2)
        assert f"blk{i}/attn" in cands      # mid-block boundary (fused add1)
    # raw attention output (pre-residual) is never a candidate:
    raw = {f"blk{i}/add1" for i in range(DENSE.n_layers)}
    assert not (raw & cands)


def test_graph_flops_match_param_count():
    g = make_graph(DENSE, batch=1, seq=16)
    # lm_head's +d stands in for final_norm's scale: exact match
    assert g.total_param_elems() == DENSE.param_count()


def test_segments_run_and_align(dense_params):
    m = make_segments(dense_params, DENSE, seq=16)
    m.verify_alignment()
    toks = _tokens(1, 16, DENSE.vocab, seed=11)
    out = m.full_apply(toks)
    ref, _ = forward(dense_params, toks, DENSE)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_collaborative_lm_end_to_end(dense_params):
    from repro.core.collab import CollaborativeEngine
    m = make_segments(dense_params, DENSE, seq=16)
    toks = _tokens(1, 16, DENSE.vocab, seed=13)
    truth = m.full_apply(toks)
    eng = CollaborativeEngine(m, "blk1/ffn")
    got, rec = eng.infer(toks)
    rel = float(jnp.linalg.norm(got - truth) / jnp.linalg.norm(truth))
    assert rel < 0.15
    assert rec.precision == "int8"


def test_param_count_formula_matches_init():
    for cfg, pf in ((DENSE, None), (MOE, None)):
        p = init_lm(jax.random.PRNGKey(2), cfg)
        n = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(p))
        assert n == cfg.param_count(), (cfg.name, n, cfg.param_count())
