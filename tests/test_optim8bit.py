"""8-bit blockwise AdamW tests (the quantized-optimizer beyond-paper
feature that fits grok-314B training on a 16 GB/chip pod)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optim import (AdamWConfig, _blockwise_dequantize,
                               _blockwise_quantize, adamw8bit_init,
                               adamw8bit_update, adamw_init, adamw_update)

jax.config.update("jax_platform_name", "cpu")


def test_blockwise_roundtrip_error_bounded():
    rng = np.random.RandomState(0)
    # blocks with wildly different magnitudes — per-block scales shine
    x = jnp.asarray(np.concatenate([rng.randn(4, 128) * 1e-4,
                                    rng.randn(4, 128) * 10.0],
                                   axis=1).astype(np.float32))
    q, s = _blockwise_quantize(x, signed=True)
    back = _blockwise_dequantize(q, s)
    rel = np.asarray(jnp.abs(back - x) / (jnp.abs(x) + 1e-12))
    assert np.median(rel) < 0.01
    assert q.dtype == jnp.int8
    assert s.shape == (4, 2)                      # one scale per 128-block


def test_blockwise_handles_odd_shapes():
    x = jnp.asarray(np.random.RandomState(1).randn(7).astype(np.float32))
    q, s = _blockwise_quantize(x, signed=True)    # falls back to per-tensor
    back = _blockwise_dequantize(q, s)
    assert float(jnp.max(jnp.abs(back - x))) < float(s) * 1.01


def test_8bit_adamw_converges_like_fp32():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p32 = {"w": jnp.zeros((256,))}
    p8 = {"w": jnp.zeros((256,))}
    o32, o8 = adamw_init(p32), adamw8bit_init(p8)
    for _ in range(300):
        g32 = jax.grad(loss)(p32)
        p32, o32, _ = adamw_update(g32, o32, p32, cfg)
        g8 = jax.grad(loss)(p8)
        p8, o8, _ = adamw8bit_update(g8, o8, p8, cfg)
    assert float(loss(p8)) < 1e-2
    assert abs(float(loss(p8)) - float(loss(p32))) < 1e-2


def test_8bit_state_is_4x_smaller():
    p = {"w": jnp.zeros((512, 512), jnp.bfloat16)}
    o32 = adamw_init(p)
    o8 = adamw8bit_init(p)

    def nbytes(tree):
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree))

    assert nbytes(o8) < nbytes(o32) / 3.5
