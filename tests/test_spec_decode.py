"""Speculative draft/verify collaborative decode.

Covers: token-stream equivalence of the draft/verify rounds against
non-speculative greedy decode (bit-identical on the fp cache configs,
quant-tolerant on the INT8 default), mid-round slot retirement and
budget trimming, wire accounting of the [B, k, D] uplink blob and the
accept-mask downlink, and the spec-k auto-tuner (k=1 recovering the
non-speculative cost model exactly).  A hypothesis property test sweeps
k x prompt lengths straddling page boundaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import spec_k_for_lm, tune_spec_k
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, EDGE_TX2_CLASS,
                                  Channel, collab_decode_step_time,
                                  expected_accepted_tokens,
                                  speculative_round_time)
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (CollaborativeServingEngine, _MSG_BYTES,
                                _QP_BYTES, _TOK_BYTES)

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="spec-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8

# fp paged config: exercises every structural piece of the speculative
# path (paged q-block verify, shared block table, rollback, page-boundary
# straddling) without INT8 rounding, so token streams must be exactly the
# non-speculative ones
FP_PAGED = dict(edge_paged=True, edge_int8=False,
                cloud_paged=True, cloud_int8=False)
LOSSLESS = dict(a_bits=16, edge_paged=False, edge_int8=False,
                cloud_paged=False, cloud_int8=False)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


def _engine(params, k, *, max_batch=2, max_len=64, channel=None, **kw):
    return CollaborativeServingEngine(params, CFG, cut_layer=1,
                                      max_batch=max_batch, max_len=max_len,
                                      page_size=PAGE, spec_k=k,
                                      channel=channel, **kw)


@pytest.fixture(scope="module")
def fp_engines(params):
    """One engine per k, reused across tests/examples (pages are fully
    reclaimed after every generate, so the engines are reusable)."""
    return {k: _engine(params, k, **FP_PAGED) for k in (1, 2, 4, 8)}


# ---------------------------------------------------------------------------
# Equivalence with non-speculative greedy decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 4, 8])
def test_spec_matches_greedy_paged_fp(fp_engines, k):
    """Draft/verify rounds over the paged caches commit exactly the
    non-speculative greedy stream — prompt lengths straddle the page
    boundary and outnumber the slots, so slots retire and recycle
    mid-flight."""
    prompts = _prompts((7, 8, 9, 15, 16), seed=1)
    ref = fp_engines[1].generate(prompts, max_new_tokens=6)
    got = fp_engines[k].generate(prompts, max_new_tokens=6)
    assert got == ref


@pytest.mark.parametrize("k", [2, 4, 8])
def test_spec_matches_greedy_lossless_dense(params, k):
    """Same equivalence on the PR-1-era dense fp config at a 16-bit
    lattice: the round restructuring is lossless."""
    prompts = _prompts((6, 9, 7), seed=2)
    base = _engine(params, 1, max_batch=3, **LOSSLESS)
    spec = _engine(params, k, max_batch=3, **LOSSLESS)
    assert spec.generate(prompts, max_new_tokens=8) == \
        base.generate(prompts, max_new_tokens=8)


def test_spec_int8_default_tracks_nonspec(params):
    """On the default INT8 caches the batched verify quantizes K/V in a
    different program order than the serial step, so near-tie argmaxes
    may flip — require the prefill tokens to agree exactly and the
    streams to mostly agree (the PR-2 tolerance for INT8 configs)."""
    prompts = _prompts((6, 9, 7), seed=3)
    ref = _engine(params, 1, max_batch=3).generate(prompts,
                                                   max_new_tokens=6)
    got = _engine(params, 4, max_batch=3).generate(prompts,
                                                   max_new_tokens=6)
    assert [g[0] for g in got] == [r[0] for r in ref]
    agree = sum(a == b for r, g in zip(ref, got) for a, b in zip(r, g))
    assert agree / sum(len(r) for r in ref) >= 0.6, (ref, got)


def test_mid_round_retirement_trims_budget(fp_engines):
    """A k=8 round overshoots a 3-token budget: the slot must retire
    mid-round with exactly its budget, tokens still the greedy ones."""
    prompts = _prompts((7, 9), seed=4)
    ref = fp_engines[1].generate(prompts, max_new_tokens=3)
    got = fp_engines[8].generate(prompts, max_new_tokens=3)
    assert got == ref
    assert all(len(g) == 3 for g in got)


def test_k1_is_the_nonspeculative_engine(params):
    """spec_k=1 must not build any draft machinery — it IS the PR-1
    incremental path."""
    eng = _engine(params, 1)
    assert not hasattr(eng, "_draft_cache")
    assert eng._round_headroom() == 0
    got = eng.generate(_prompts((6, 9), seed=5), max_new_tokens=4)
    ref = CollaborativeServingEngine(
        init_lm(jax.random.PRNGKey(0), CFG), CFG, cut_layer=1, max_batch=2,
        max_len=64, page_size=PAGE).generate(_prompts((6, 9), seed=5),
                                             max_new_tokens=4)
    assert got == ref


# ---------------------------------------------------------------------------
# Wire accounting (per-accepted-token, accept-mask downlink)
# ---------------------------------------------------------------------------


def test_spec_round_wire_accounting(params):
    """Every round's uplink is k per-row-framed deltas + the k-1 graded
    draft ids + one header; every downlink is the corrected token + the
    byte-packed accept mask + one header; tokens are counted as
    *accepted*."""
    k, new = 4, 6
    eng = _engine(params, k, max_batch=1, channel=Channel.from_kbps(100),
                  **FP_PAGED)
    outs = eng.generate(_prompts((9,), seed=6), max_new_tokens=new)
    s = eng.stats
    assert len(outs[0]) == new
    rounds = s.decode_steps
    assert s.spec_rounds == rounds
    per_round_up = k * (CFG.d_model + _QP_BYTES) + (k - 1) * _TOK_BYTES \
        + _MSG_BYTES
    assert s.decode_bytes == rounds * per_round_up
    assert s.decode_bytes_log == [per_round_up] * rounds
    per_round_down = (_TOK_BYTES + 1) + _MSG_BYTES      # ceil(4/8) = 1 mask
    assert s.decode_downlink_bytes == rounds * per_round_down
    # accepted-token accounting: the prefill token is not a decode token
    assert s.decode_tokens == new - 1
    assert s.bytes_per_decode_token() == \
        pytest.approx(rounds * per_round_up / (new - 1))
    assert s.wire_bytes_per_accepted_token() == \
        pytest.approx(rounds * (per_round_up + per_round_down) / (new - 1))
    # the verify graded k-1 drafts per round; hits within [0, k-1]
    assert s.drafted_tokens == rounds * (k - 1)
    assert 0.0 <= s.acceptance_rate() <= 1.0


def test_spec_rounds_amortize_channel_rtt(params):
    """With a high-RTT channel the speculative engine pays the RTT per
    round instead of per token: simulated channel latency must drop."""
    ch = Channel.from_kbps(500, rtt_ms=50)
    prompts = _prompts((8, 8), seed=7)
    base = _engine(params, 1, channel=ch, **FP_PAGED)
    base.generate(prompts, max_new_tokens=8)
    spec = _engine(params, 4, channel=ch, **FP_PAGED)
    spec.generate(prompts, max_new_tokens=8)
    assert spec.stats.channel_latency_s < base.stats.channel_latency_s


# ---------------------------------------------------------------------------
# Spec-k auto-tuner (costmodel.speculative_round_time + autotune)
# ---------------------------------------------------------------------------


def test_spec_round_time_k1_recovers_step_model():
    kw = dict(edge_flops=1e7, cloud_flops=5e7, blob_bytes=1056.0,
              edge=EDGE_TX2_CLASS, cloud=CLOUD_TITANXP_CLASS,
              channel=Channel.from_kbps(250, rtt_ms=20), return_bytes=16.0)
    step = collab_decode_step_time(**kw)
    rnd = speculative_round_time(k=1, draft_flops=5e7, acceptance=0.5,
                                 rows=4, **kw)
    assert rnd.decode_s == step.decode_s
    assert rnd.channel_s == step.channel_s
    assert rnd.tokens == 1.0


def test_expected_accepted_tokens():
    assert expected_accepted_tokens(1, 0.3) == 1.0
    assert expected_accepted_tokens(4, 1.0) == 4.0
    e = expected_accepted_tokens(3, 0.5)
    assert e == pytest.approx(1 + 0.5 + 0.25)


def test_tuner_picks_k_by_channel():
    kw = dict(edge_flops=1e7, cloud_flops=5e7, draft_flops=5e7,
              blob_bytes=1056.0, edge=EDGE_TX2_CLASS,
              cloud=CLOUD_TITANXP_CLASS, acceptance=0.9, rows=4,
              return_bytes=16.0)
    slow, _ = tune_spec_k(channel=Channel.from_kbps(250, rtt_ms=50), **kw)
    fast, perfs = tune_spec_k(channel=Channel(bandwidth_bytes_per_s=1e15),
                              **kw)
    assert slow.k > 1
    assert fast.k == 1            # no RTT to amortize -> serial step wins
    assert any(p.k == 1 for p in perfs)


def test_engine_auto_spec_k(params):
    slow = _engine(params, "auto", channel=Channel.from_kbps(100, rtt_ms=50))
    assert slow.spec_k > 1
    fast = _engine(params, "auto")      # infinite default channel
    assert fast.spec_k == 1
    lm = spec_k_for_lm(CFG, 1, batch=2,
                       channel=Channel.from_kbps(100, rtt_ms=50))[0]
    assert lm.k == slow.spec_k


# ---------------------------------------------------------------------------
# Property test: k x prompt lengths straddling page boundaries
# ---------------------------------------------------------------------------

# guarded like the rest of the tier-1 property tests: hypothesis missing
# must skip only this test, never kill collection of the module
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(k=st.sampled_from([1, 2, 4, 8]),
           plens=st.lists(st.integers(min_value=5, max_value=18),
                          min_size=1, max_size=4),
           max_new=st.integers(min_value=2, max_value=7),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_spec_decode_bit_identical_property(fp_engines, k, plens,
                                                max_new, seed):
        """For any k, any prompt lengths around the page boundary (page
        8: lengths 5..18 cover <1, =1, >1, =2, >2 pages), any budget
        (odd budgets force mid-round retirement for k in {2, 4, 8}),
        speculative decode commits exactly the non-speculative greedy
        stream."""
        prompts = _prompts(plens, seed=seed)
        ref = fp_engines[1].generate(prompts, max_new_tokens=max_new)
        got = fp_engines[k].generate(prompts, max_new_tokens=max_new)
        assert got == ref
        assert all(len(g) == max_new for g in got)
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_spec_decode_bit_identical_property():
        pass
