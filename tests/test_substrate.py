"""Training-substrate tests: optimizer, data, checkpointing, fault
tolerance, gradient compression, QAT, trainer loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ImagePipeline, Prefetcher, TokenPipeline
from repro.distributed.checkpoint import (CheckpointManager, latest_step,
                                          restore_checkpoint,
                                          save_checkpoint)
from repro.distributed.ft import (HeartbeatMonitor, TrainSupervisor,
                                  WorkerFailure, plan_elastic_mesh)
from repro.train.grad_compress import (compress_with_feedback,
                                       compressed_allreduce_bytes,
                                       init_error_feedback)
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule)
from repro.train.qat import make_qat_loss

jax.config.update("jax_platform_name", "cpu")


# ----------------------------- optimizer -----------------------------------

def _quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}


def test_adamw_converges_on_quadratic():
    p = _quad_params()
    opt = adamw_init(p)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(p)
        p, opt, _ = adamw_update(g, opt, p, cfg)
    assert float(loss(p)) < 1e-3
    assert int(opt.step) == 200


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2)
                         for x in jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=1e-6)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(jnp.int32(55))) == pytest.approx(0.5, abs=0.01)


# ------------------------------- data ---------------------------------------

def test_token_pipeline_deterministic_and_rank_disjoint():
    p0 = TokenPipeline(vocab=64, seq_len=16, batch=4, seed=1, rank=0, world=2)
    p0b = TokenPipeline(vocab=64, seq_len=16, batch=4, seed=1, rank=0, world=2)
    p1 = TokenPipeline(vocab=64, seq_len=16, batch=4, seed=1, rank=1, world=2)
    b0, b0b, b1 = p0.batch_at(5), p0b.batch_at(5), p1.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_image_pipeline_learnable_signal():
    p = ImagePipeline(img_res=16, batch=8, n_classes=3, seed=0)
    b = p.batch_at(0)
    assert b["image"].shape == (8, 16, 16, 3)
    assert set(np.unique(b["label"])) <= {0, 1, 2}


def test_prefetcher_yields_in_order():
    pipe = TokenPipeline(vocab=16, seq_len=4, batch=2, seed=3)
    pf = Prefetcher(iter(pipe), depth=2)
    got = next(pf)
    np.testing.assert_array_equal(got["tokens"], pipe.batch_at(0)["tokens"])
    got2 = next(pf)
    np.testing.assert_array_equal(got2["tokens"], pipe.batch_at(1)["tokens"])
    pf.close()


# ----------------------------- checkpoint -----------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "opt": {"m": jnp.ones(3)},
            "step": jnp.int32(7)}
    save_checkpoint(tmp_path, 42, tree, metadata={"note": "hi"})
    restored, step, meta = restore_checkpoint(tmp_path, tree)
    assert step == 42 and meta["note"] == "hi"
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["step"].dtype == jnp.int32


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_000000003", "step_000000004"]


def test_checkpoint_restore_to_different_sharding(tmp_path):
    """Elastic restart: leaves restore onto any current-mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import _mk_mesh
    mesh = _mk_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    save_checkpoint(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, every=2, async_save=True)
    tree = {"x": jnp.ones(4)}
    assert not mgr.maybe_save(1, tree)
    assert mgr.maybe_save(2, tree)
    mgr.wait()
    assert latest_step(tmp_path) == 2


# -------------------------- fault tolerance ----------------------------------

def test_heartbeat_detects_dead_and_straggler():
    mon = HeartbeatMonitor(n_ranks=4, timeout_s=5.0, straggler_factor=2.0)
    now = 100.0
    for r in range(4):
        mon.beat(r, step_time_s=1.0 if r != 2 else 5.0, now=now)
    # everyone beat at t=100 → all alive at t=103
    assert mon.dead_ranks(now=103.0) == []
    mon.beat(0, now=103.0)
    # at t=106 only rank 0 (last beat 103) is within the 5 s timeout
    assert mon.dead_ranks(now=106.0) == [1, 2, 3]
    assert mon.stragglers() == [2]
    assert 2 not in mon.healthy_ranks()


def test_plan_elastic_mesh_shrinks_data_axis():
    assert plan_elastic_mesh(256, model_parallel=16) == (16, 16)
    assert plan_elastic_mesh(240, model_parallel=16) == (15, 16)
    assert plan_elastic_mesh(8, model_parallel=16) == (1, 8)


def test_supervisor_restart_is_bit_exact(tmp_path):
    """Training with injected failures must produce the same final state
    as an uninterrupted run (deterministic data keyed by step)."""

    def make_step(fail_at=frozenset()):
        fired = set()

        def step_fn(state, step):
            if step in fail_at and step not in fired:
                fired.add(step)
                raise WorkerFailure(f"node died at {step}")
            new = {"w": state["w"] + 0.5 ** (step + 1)}
            return new, {"w": float(new["w"])}
        return step_fn

    clean_sup = TrainSupervisor(str(tmp_path / "clean"), ckpt_every=1)
    clean, _ = clean_sup.run({"w": jnp.float32(0.0)}, make_step(), 8)

    faulty_sup = TrainSupervisor(str(tmp_path / "faulty"), ckpt_every=1)
    faulty, hist = faulty_sup.run({"w": jnp.float32(0.0)},
                                  make_step(fail_at={3, 6}), 8)
    assert float(clean["w"]) == pytest.approx(float(faulty["w"]), abs=1e-7)


# ------------------------- gradient compression ------------------------------

def test_error_feedback_preserves_long_run_average():
    """Sum of transmitted grads ≈ sum of true grads (EF property)."""
    rng = np.random.RandomState(0)
    grads = [{"w": jnp.asarray(rng.randn(64).astype(np.float32))}
             for _ in range(50)]
    err = init_error_feedback(grads[0])
    sent_sum = jnp.zeros(64)
    true_sum = jnp.zeros(64)
    for g in grads:
        sent, err = compress_with_feedback(g, err)
        sent_sum = sent_sum + sent["w"]
        true_sum = true_sum + g["w"]
    resid = float(jnp.max(jnp.abs(sent_sum - true_sum)))
    # leftover residual is bounded by one quantization step
    assert resid < 0.05


def test_compression_rate_is_4x():
    params = {"w": jnp.zeros((1024,)), "b": jnp.zeros((8,))}
    fp, comp = compressed_allreduce_bytes(params)
    assert fp == 1032 * 4
    assert comp < fp / 3


def test_sgd_with_compression_still_converges():
    p = {"w": jnp.array([4.0, -3.0])}
    err = init_error_feedback(p)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(80):
        g = jax.grad(loss)(p)
        sent, err = compress_with_feedback(g, err)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, p, sent)
    assert float(loss(p)) < 1e-3


# --------------------------------- QAT ---------------------------------------

def test_qat_training_tracks_fp32(tmp_path):
    """QAT on a tiny MLP: quantized loss should track fp32 loss closely."""
    from repro.models import layers as L

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {"l1": L.dense_init(k1, 8, 16), "l2": L.dense_init(k2, 16, 1)}
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    y = jnp.asarray((x[:, :1] * 2 - x[:, 1:2]))

    def model_loss(p, batch, qctx=None):
        h = L.dense(p["l1"], batch["x"], qctx=qctx, name="l1", act="relu")
        out = L.dense(p["l2"], h, qctx=qctx, name="l2")
        return jnp.mean((out - batch["y"]) ** 2)

    batch = {"x": x, "y": y}
    qat = make_qat_loss(model_loss)
    vg = jax.jit(jax.value_and_grad(qat))
    p = params
    for _ in range(150):
        l, g = vg(p, batch)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
    fp32_after = float(model_loss(p, batch))
    qat_after = float(qat(p, batch))
    assert qat_after < 0.1                      # QAT converged
    assert abs(fp32_after - qat_after) < 0.05   # lattice ≈ fp32 behaviour


# ------------------------------- trainer -------------------------------------

def test_trainer_end_to_end_with_ckpt_and_accum(tmp_path):
    from repro.models import layers as L

    key = jax.random.PRNGKey(2)
    params = {"l1": L.dense_init(key, 4, 8),
              "l2": L.dense_init(jax.random.fold_in(key, 1), 8, 2)}

    def loss(p, batch):
        h = L.dense(p["l1"], batch["x"], act="relu", name="l1")
        logits = L.dense(p["l2"], h, name="l2")
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(logz - gold)

    rng = np.random.RandomState(3)

    def data():
        step = 0
        while True:
            x = rng.randn(4, 8, 4).astype(np.float32)   # accum=4 microbatches
            y = (x.sum(-1) > 0).astype(np.int32)
            yield {"x": x, "y": y}
            step += 1

    cfg = TrainerConfig(n_steps=12, lr=0.05, warmup=2, grad_accum=4,
                        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    tr = Trainer(loss, params, cfg)
    hist = tr.fit(data())
    assert len(hist) == 12
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert latest_step(tmp_path) == 10
    # restore resumes from the checkpoint
    tr2 = Trainer(loss, params, cfg)
    start = tr2.maybe_restore()
    assert start == 10
