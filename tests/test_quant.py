"""Unit + property tests for repro.core.quant (paper §2.1 Eq.1/2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip, don't "
    "kill collection of the whole tier-1 suite")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.quant import (
    EMACalibrator,
    MinMaxCalibrator,
    PercentileCalibrator,
    QuantParams,
    compute_qparams,
    dequantize,
    dequantize_pytree,
    fake_quant,
    pytree_quant_bytes,
    quantize,
    quantize_pytree,
)

jax.config.update("jax_platform_name", "cpu")


def test_roundtrip_error_bounded_by_half_scale():
    x = jnp.array(np.random.RandomState(0).uniform(-3, 5, size=(256,)),
                  jnp.float32)
    qp = compute_qparams(x)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    assert float(jnp.max(err)) <= float(qp.scale) / 2 + 1e-6


def test_paper_eq1_eq2_unsigned_matches_formula():
    """Check our affine code IS the paper's Eq.1/Eq.2 (unsigned repr)."""
    rng = np.random.RandomState(1)
    x = rng.uniform(-2.0, 6.0, size=(512,)).astype(np.float32)
    t_min, t_max = float(x.min()), float(x.max())
    qp = compute_qparams(jnp.asarray(x), signed=False)
    q = np.asarray(quantize(jnp.asarray(x), qp), np.float64)
    # Paper Eq.1 (interior points): (x - Tmin)/|Tmax-Tmin| * 255
    expect = np.clip(np.round((x - t_min) / abs(t_max - t_min) * 255), 0, 255)
    assert np.max(np.abs(q - expect)) <= 1.0   # ≤1 ulp from zero-point rounding
    # Paper Eq.2: scale*q + Tmin
    deq = np.asarray(dequantize(quantize(jnp.asarray(x), qp), qp))
    expect_deq = abs(t_max - t_min) / 255 * q + t_min
    np.testing.assert_allclose(deq, expect_deq, atol=float(qp.scale) * 1.01)


def test_signed_unsigned_same_lattice():
    x = jnp.array(np.random.RandomState(2).uniform(-1, 2, (128,)), jnp.float32)
    qs = compute_qparams(x, signed=True)
    qu = compute_qparams(x, signed=False)
    np.testing.assert_allclose(
        np.asarray(dequantize(quantize(x, qs), qs)),
        np.asarray(dequantize(quantize(x, qu), qu)), atol=1e-6)
    # signed q == unsigned q - 128
    np.testing.assert_array_equal(
        np.asarray(quantize(x, qs), np.int32),
        np.asarray(quantize(x, qu), np.int32) - 128)


def test_saturation_clips_to_extremes():
    qp = compute_qparams(jnp.array([-1.0, 1.0]))
    q = quantize(jnp.array([-100.0, 100.0]), qp)
    assert int(q[0]) == qp.qmin and int(q[1]) == qp.qmax


def test_zero_exactly_representable():
    x = jnp.array(np.random.RandomState(3).uniform(0.5, 3.0, (64,)), jnp.float32)
    qp = compute_qparams(x)   # all-positive data still must represent 0
    z = dequantize(quantize(jnp.zeros(()), qp), qp)
    assert abs(float(z)) < 1e-6


def test_per_channel_beats_or_matches_per_tensor():
    rng = np.random.RandomState(4)
    w = np.concatenate([rng.uniform(-0.01, 0.01, (64, 8)),
                        rng.uniform(-10, 10, (64, 8))], axis=1).astype(np.float32)
    w = jnp.asarray(w)
    qp_t = compute_qparams(w)
    qp_c = compute_qparams(w, axis=1)
    # Per-channel scales rescue the small-magnitude channels (cols 0..7);
    # per-tensor is forced to use the global ±10 range there.
    small = slice(0, 8)
    err_t = float(jnp.mean(
        (dequantize(quantize(w, qp_t), qp_t) - w)[:, small] ** 2))
    err_c = float(jnp.mean(
        (dequantize(quantize(w, qp_c), qp_c) - w)[:, small] ** 2))
    assert err_c < err_t / 100


def test_fake_quant_gradient_is_straight_through():
    x = jnp.linspace(-1.0, 1.0, 11)
    qp = compute_qparams(x)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, qp)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(11), atol=1e-6)
    # saturated region has zero gradient
    far = jnp.array([100.0, -100.0])
    g2 = jax.grad(lambda v: jnp.sum(fake_quant(v, qp)))(far)
    np.testing.assert_allclose(np.asarray(g2), np.zeros(2), atol=1e-6)


def test_calibrators_agree_on_stationary_stream():
    rng = np.random.RandomState(5)
    batches = [jnp.asarray(rng.uniform(-1, 1, (1024,)).astype(np.float32))
               for _ in range(8)]
    mm, ema = MinMaxCalibrator(), EMACalibrator(momentum=0.5)
    pct = PercentileCalibrator(percentile=100.0)
    for b in batches:
        mm.observe(b); ema.observe(b); pct.observe(b)
    s_mm = float(mm.qparams().scale)
    s_ema = float(ema.qparams().scale)
    s_pct = float(pct.qparams().scale)
    assert abs(s_mm - s_pct) / s_mm < 0.05
    assert abs(s_mm - s_ema) / s_mm < 0.2


def test_percentile_robust_to_outliers():
    rng = np.random.RandomState(6)
    data = rng.uniform(-1, 1, 100000).astype(np.float32)
    data[0] = 1e6   # single huge outlier
    mm, pc = MinMaxCalibrator(), PercentileCalibrator(99.9)
    mm.observe(jnp.asarray(data)); pc.observe(jnp.asarray(data))
    assert float(pc.qparams().scale) < float(mm.qparams().scale) / 100


def test_pytree_roundtrip_and_storage():
    params = {"w": jnp.ones((16, 32)) * 0.5, "b": jnp.zeros((32,)),
              "step": jnp.array(3, jnp.int32)}
    qt, qpt = quantize_pytree(params)
    back = dequantize_pytree(qt, qpt)
    np.testing.assert_allclose(np.asarray(back["w"]), 0.5, atol=1e-2)
    assert back["step"].dtype == jnp.int32          # non-float passthrough
    fp, qb = pytree_quant_bytes(params)
    assert fp == (16 * 32 + 32 + 1) * 4
    assert qb < fp / 3.5                            # ~4x reduction


# ----------------------------- property tests ------------------------------

finite_f32 = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                       allow_infinity=False, width=32)


@settings(max_examples=60, deadline=None)
@given(st.lists(finite_f32, min_size=2, max_size=200), st.booleans())
def test_prop_roundtrip_bounded(vals, signed):
    x = jnp.asarray(np.array(vals, np.float32))
    qp = compute_qparams(x, signed=signed)
    err = jnp.max(jnp.abs(dequantize(quantize(x, qp), qp) - x))
    assert float(err) <= float(qp.scale) * 0.5001 + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.lists(finite_f32, min_size=2, max_size=100))
def test_prop_quantize_monotone(vals):
    x = jnp.sort(jnp.asarray(np.array(vals, np.float32)))
    qp = compute_qparams(x)
    q = np.asarray(quantize(x, qp), np.int32)
    assert np.all(np.diff(q) >= 0)


@settings(max_examples=40, deadline=None)
@given(st.lists(finite_f32, min_size=2, max_size=100),
       st.integers(min_value=2, max_value=8))
def test_prop_more_bits_no_worse(vals, bits):
    x = jnp.asarray(np.array(vals, np.float32))
    lo = compute_qparams(x, bits=bits)
    hi = compute_qparams(x, bits=bits + 4)
    err_lo = float(jnp.mean((dequantize(quantize(x, lo), lo) - x) ** 2))
    err_hi = float(jnp.mean((dequantize(quantize(x, hi), hi) - x) ** 2))
    assert err_hi <= err_lo + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=64))
def test_prop_quantize_jit_consistent(n):
    x = jnp.asarray(np.random.RandomState(n).randn(n).astype(np.float32))
    qp = compute_qparams(x)
    eager = quantize(x, qp)
    jitted = jax.jit(quantize)(x, qp)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
