"""Diffusion model-family tests (reduced configs, CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import candidate_partition_points
from repro.models import mmdit, unet

jax.config.update("jax_platform_name", "cpu")

TINY_UNET = unet.UNetConfig(name="tiny-unet", ch=8, ch_mult=(1, 2, 2),
                            n_res_blocks=1, attn_stages=(0, 1), ctx_dim=16,
                            ctx_len=4, n_heads=2, img_res=64)
TINY_MMDIT = mmdit.MMDiTConfig(name="tiny-mmdit", n_double=2, n_single=3,
                               d_model=32, n_heads=4, img_res=64, txt_len=4,
                               txt_dim=24, vec_dim=12, in_ch=8, remat=False)


def test_unet_forward_shapes():
    cfg = TINY_UNET
    p = unet.init_unet(jax.random.PRNGKey(0), cfg)
    r = cfg.latent_res
    x = jnp.asarray(np.random.RandomState(0).randn(2, r, r, 4), jnp.float32)
    t = jnp.array([10, 500], jnp.int32)
    ctx = jnp.asarray(np.random.RandomState(1).randn(2, cfg.ctx_len,
                                                     cfg.ctx_dim), jnp.float32)
    eps = unet.unet_forward(p, x, t, ctx, cfg)
    assert eps.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(eps)))


def test_unet_loss_decreases():
    cfg = TINY_UNET
    p = unet.init_unet(jax.random.PRNGKey(1), cfg)
    r = cfg.latent_res
    rng = np.random.RandomState(2)
    batch = {"latent": jnp.asarray(rng.randn(2, r, r, 4), jnp.float32),
             "ctx": jnp.asarray(rng.randn(2, cfg.ctx_len, cfg.ctx_dim),
                                jnp.float32)}
    key = jax.random.PRNGKey(3)
    vg = jax.jit(jax.value_and_grad(
        lambda p, k: unet.diffusion_loss(p, batch, cfg, rng=k)))
    l0, _ = vg(p, key)
    for i in range(4):
        l, g = vg(p, jax.random.fold_in(key, i))
        p = jax.tree_util.tree_map(lambda a, b: a - 0.2 * b, p, g)
    l1, _ = vg(p, key)
    assert float(l1) < float(l0)


def test_unet_ddim_step_moves_toward_x0():
    cfg = TINY_UNET
    p = unet.init_unet(jax.random.PRNGKey(4), cfg)
    r = cfg.latent_res
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(1, r, r, 4), jnp.float32)
    ctx = jnp.asarray(rng.randn(1, cfg.ctx_len, cfg.ctx_dim), jnp.float32)
    t = jnp.array([999], jnp.int32)
    x2 = unet.ddim_step(p, x, t, jnp.array([899]), ctx, cfg)
    assert x2.shape == x.shape and bool(jnp.all(jnp.isfinite(x2)))


def test_unet_graph_skips_exclude_encoder_cuts():
    g = unet.make_graph(unet.UNetConfig(name="sd15"), batch=1)
    cands = {c.name for c in candidate_partition_points(g)}
    assert "conv_in" in cands
    # interior encoder cuts are spanned by live long skips → excluded.
    # (down0 itself survives: at that cut the ONE tensor feeds both the
    # downsample and the skip, so it is legitimately single-blob.)
    for s in range(1, 4):
        assert f"down{s}" not in cands
    assert f"down{0}/ds" not in cands
    assert "mid" not in cands
    for s in (1, 2, 3):
        assert f"up{s}" not in cands      # skips still live
    # after the last skip is consumed the decoder tail is single-blob
    assert "up0" in cands and "conv_out" in cands


def test_sd15_param_count_ballpark():
    cfg = unet.UNetConfig(name="sd15")
    p = jax.eval_shape(lambda k: unet.init_unet(k, cfg),
                       jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))
    assert 0.75e9 < n < 1.0e9        # SD1.5 UNet ≈ 0.86B


def test_mmdit_forward_shapes():
    cfg = TINY_MMDIT
    p = mmdit.init_mmdit(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.randn(2, cfg.n_img_tokens, cfg.in_ch), jnp.float32)
    txt = jnp.asarray(rng.randn(2, cfg.txt_len, cfg.txt_dim), jnp.float32)
    vec = jnp.asarray(rng.randn(2, cfg.vec_dim), jnp.float32)
    t = jnp.array([0.1, 0.9], jnp.float32)
    v = mmdit.mmdit_forward(p, img, t, txt, vec, cfg)
    assert v.shape == img.shape
    assert bool(jnp.all(jnp.isfinite(v)))


def test_mmdit_rf_loss_decreases():
    cfg = TINY_MMDIT
    p = mmdit.init_mmdit(jax.random.PRNGKey(1), cfg)
    rng = np.random.RandomState(1)
    batch = {"latent": jnp.asarray(rng.randn(2, cfg.n_img_tokens, cfg.in_ch),
                                   jnp.float32),
             "txt": jnp.asarray(rng.randn(2, cfg.txt_len, cfg.txt_dim),
                                jnp.float32),
             "vec": jnp.asarray(rng.randn(2, cfg.vec_dim), jnp.float32)}
    key = jax.random.PRNGKey(2)
    vg = jax.jit(jax.value_and_grad(
        lambda p, k: mmdit.rf_loss(p, batch, cfg, rng=k)))
    l0, _ = vg(p, key)
    for i in range(4):
        l, g = vg(p, jax.random.fold_in(key, i))
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
    l1, _ = vg(p, key)
    assert float(l1) < float(l0)


def test_mmdit_dual_stream_partition_structure():
    g = mmdit.make_graph(TINY_MMDIT, batch=1)
    single = {c.name for c in candidate_partition_points(
        g, include_input=False, include_last=False)}
    # interior double-block cuts are never single-blob; the LAST double
    # block's txt node hosts the fused stream-merge concat and is the one
    # legal 1-blob boundary in the double region.
    n_dbl = TINY_MMDIT.n_double
    assert not any(c.startswith("dbl") and not c.startswith(
        f"dbl{n_dbl - 1}/txt") for c in single)
    assert f"dbl{n_dbl - 1}/txt" in single
    # single-stream blocks are ordinary 1-blob boundaries
    assert any(c.startswith("sgl") for c in single)
    dual = {c.name for c in candidate_partition_points(
        g, max_blobs=2, include_input=False, include_last=False)}
    # DESIGN.md extension: double-block boundaries appear at max_blobs=2
    assert any(c.startswith("dbl") for c in dual)


def test_flux_dev_param_count_ballpark():
    cfg = mmdit.MMDiTConfig(name="flux-dev")
    p = jax.eval_shape(lambda k: mmdit.init_mmdit(k, cfg),
                       jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))
    assert 10e9 < n < 14e9           # flux-dev ≈ 12B
    assert abs(n - cfg.param_count()) / n < 0.02
