"""Vision model-family tests (reduced configs, CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import candidate_partition_points
from repro.models import legacy, resnet, vit

jax.config.update("jax_platform_name", "cpu")

TINY_VIT = vit.ViTConfig(name="tiny-vit", img_res=32, patch=8, n_layers=2,
                         d_model=32, n_heads=4, d_ff=64, n_classes=10,
                         remat=False)
TINY_DEIT = vit.ViTConfig(name="tiny-deit", img_res=32, patch=8, n_layers=2,
                          d_model=32, n_heads=4, d_ff=64, n_classes=10,
                          distill_token=True, remat=False)
TINY_RESNET = resnet.ResNetConfig(name="tiny-resnet", depths=(1, 1, 1, 1),
                                  width=8, bottleneck=True, n_classes=10,
                                  img_res=32)
TINY_BASIC = resnet.ResNetConfig(name="tiny-basic", depths=(1, 1, 1, 1),
                                 width=8, bottleneck=False, n_classes=10,
                                 img_res=32)


def _img(batch=2, res=32, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).rand(batch, res, res, 3).astype(np.float32))


@pytest.mark.parametrize("cfg", [TINY_VIT, TINY_DEIT])
def test_vit_forward_shapes(cfg):
    p = vit.init_vit(jax.random.PRNGKey(0), cfg)
    logits = vit.forward(p, _img(res=cfg.img_res), cfg)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_vit_param_count_formula():
    for cfg in (TINY_VIT, TINY_DEIT):
        p = vit.init_vit(jax.random.PRNGKey(1), cfg)
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))
        assert n == cfg.param_count(), (cfg.name, n, cfg.param_count())


def test_vit_loss_decreases():
    cfg = TINY_VIT
    p = vit.init_vit(jax.random.PRNGKey(2), cfg)
    batch = {"image": _img(4, cfg.img_res),
             "label": jnp.arange(4, dtype=jnp.int32) % cfg.n_classes}
    vg = jax.jit(jax.value_and_grad(lambda p: vit.cls_loss(p, batch, cfg)))
    l0, g = vg(p)
    for _ in range(5):
        l, g = vg(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.5 * b, p, g)
    assert float(vg(p)[0]) < float(l0)


def test_vit_candidates_are_block_boundaries():
    g = vit.make_graph(TINY_VIT, batch=1)
    cands = {c.name for c in candidate_partition_points(g)}
    assert {"patch", "blk0/ffn", "blk1/ffn", "head"} <= cands
    assert "blk0/add1" not in cands


def test_vit_collab_roundtrip():
    from repro.core.collab import CollaborativeEngine
    cfg = TINY_VIT
    p = vit.init_vit(jax.random.PRNGKey(3), cfg)
    m = vit.make_segments(p, cfg)
    m.verify_alignment()
    x = _img(1, cfg.img_res, seed=4)
    truth = m.full_apply(x)
    ref = vit.forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(truth), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    got, rec = CollaborativeEngine(m, "blk0/ffn").infer(x)
    rel = float(jnp.linalg.norm(got - truth) / jnp.linalg.norm(truth))
    assert rel < 0.2 and rec.precision == "int8"


@pytest.mark.parametrize("cfg", [TINY_RESNET, TINY_BASIC])
def test_resnet_forward_and_segments(cfg):
    p = resnet.init_resnet(jax.random.PRNGKey(0), cfg)
    logits = resnet.forward(p, _img(res=cfg.img_res), cfg)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    m = resnet.make_segments(p, cfg)
    m.verify_alignment()
    out = m.full_apply(_img(1, cfg.img_res))
    ref = resnet.forward(p, _img(1, cfg.img_res), cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_resnet152_graph_structure():
    cfg = resnet.ResNetConfig(name="resnet-152", depths=(3, 8, 36, 3))
    g = resnet.make_graph(cfg, batch=1)
    cands = {c.name for c in candidate_partition_points(g)}
    # stage boundaries are candidates; 50 blocks total
    n_blocks = sum(cfg.depths)
    assert n_blocks == 50
    block_cands = [c for c in cands if c.endswith("/body")]
    assert len(block_cands) == n_blocks
    # published "11.5 G" is GMACs; we count FLOPs = 2*MACs → ~23 GFLOPs
    assert 20e9 < g.total_flops() < 26e9
    # ~60M params
    assert 55e6 < g.total_param_elems() < 65e6


def test_resnet18_graph_matches_published_size():
    cfg = resnet.ResNetConfig(name="resnet-18", depths=(2, 2, 2, 2),
                              bottleneck=False)
    g = resnet.make_graph(cfg, batch=1)
    assert 10e6 < g.total_param_elems() < 13e6       # ~11.7M
    assert 3e9 < g.total_flops() < 4.5e9             # ~3.6 GFLOPs


def test_alexnet_graph_and_forward():
    g = legacy.alexnet_graph()
    assert 55e6 < g.total_param_elems() < 65e6       # ~61M params
    # ungrouped (single-tower) AlexNet: ~1.13 GMACs → ~2.3 GFLOPs
    assert 2.0e9 < g.total_flops() < 2.6e9
    p = legacy.init_alexnet(jax.random.PRNGKey(0))
    x = _img(1, 227)
    y = legacy.alexnet_forward(p, x)
    assert y.shape == (1, 1000) and bool(jnp.all(jnp.isfinite(y)))
    m = legacy.alexnet_segments(p)
    m.verify_alignment()
    np.testing.assert_allclose(np.asarray(m.full_apply(x)), np.asarray(y),
                               rtol=2e-4, atol=2e-4)


def test_vgg16_graph_counts():
    g = legacy.vgg16_graph()
    assert 130e6 < g.total_param_elems() < 145e6     # ~138M params
    assert 28e9 < g.total_flops() < 34e9             # ~31 GFLOPs
    cands = {c.name for c in candidate_partition_points(g)}
    assert "conv1_2" in cands                        # paper's best cut


def test_googlenet_graph_and_candidates():
    g = legacy.googlenet_graph()
    assert 5e6 < g.total_param_elems() < 8e6         # ~6.8M params
    assert 2.5e9 < g.total_flops() < 4e9             # ~3 GFLOPs
    cands = {c.name for c in candidate_partition_points(g)}
    assert "conv2" in cands                          # paper's best cut
    # inception interiors excluded; fused concat points are candidates
    assert "inc3a/b2b" not in cands
    assert "inc3a/b4" in cands
    # all 9 inception boundaries
    assert sum(1 for c in cands if c.endswith("/b4")) == 9


def test_googlenet_forward_small():
    p = legacy.init_googlenet(jax.random.PRNGKey(0))
    y = legacy.googlenet_forward(p, _img(1, 224))
    assert y.shape == (1, 1000) and bool(jnp.all(jnp.isfinite(y)))
    m = legacy.googlenet_segments(p)
    m.verify_alignment()
