"""Sampling-aware speculative verify (rejection sampling).

Gates the PR's distribution contract (``serve.sampling``): (a) the
rejection-sampling verify core commits tokens distributed *exactly* as
the cloud's filtered distribution — a TV-distance frequency test at the
math level, and an engine-level frequency test comparing spec_k=4
against non-speculative (spec_k=1) cloud sampling; (b) the greedy
``temperature=0`` fast path is bit-identical to the pre-sampling
engines and never traces the sampled phases; (c) the per-(seed, index,
stream) key discipline makes sampled streams deterministic across
fresh engines, preemption replay, and fleet co-batching; (d) the wire
and the cost model both price the sampled rounds' f32 q-row uplink;
(e) ``LinkTelemetry.observe_round`` treats a zero-acceptance round as
a first-class sample (routine at high temperature) — pinned here
because ``tune_spec_k`` re-tunes from that EWMA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import lm_round_args, tune_spec_k
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, EDGE_TX2_CLASS,
                                  Channel, speculative_round_time)
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         FleetServingEngine, PressureSchedule,
                         ReliableTransport, ResilientCollaborativeEngine,
                         SamplingParams, TenantSpec)
from repro.serve import sampling as S
from repro.serve.transport import (_MSG_BYTES, _QP_BYTES, _TOK_BYTES,
                                   LinkTelemetry)

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="sampled-tiny", n_layers=3, d_model=32, n_heads=4,
               n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8
# bitwise oracles need the lossless fp configuration (same convention as
# tests/test_fleet_serve.py): no INT8 rounding anywhere on the path
LOSSLESS = dict(a_bits=None, edge_int8=False, cloud_int8=False)
BASE_CH = Channel.from_kbps(500, rtt_ms=10)
SP = SamplingParams(temperature=0.8, top_p=0.9, seed=11)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


def _engine(params, k, *, max_batch=4, **kw):
    cfg = dict(LOSSLESS)
    cfg.update(kw)
    return CollaborativeServingEngine(params, CFG, cut_layer=1,
                                      max_batch=max_batch, max_len=64,
                                      page_size=PAGE, spec_k=k, **cfg)


def _tv(counts_a, counts_b):
    pa = counts_a / counts_a.sum()
    pb = counts_b / counts_b.sum()
    return 0.5 * float(np.abs(pa - pb).sum())


# ---------------------------------------------------------------------------
# The rejection-sampling core is exact (math-level TV gate)
# ---------------------------------------------------------------------------


def test_grade_and_correct_matches_target_distribution():
    """Committed tokens are distributed per the CLOUD filtered
    distribution p regardless of the draft distribution q — both the
    graded position (accept-or-residual) and the all-accepted bonus."""
    B, k, V = 4096, 2, 8
    rng = np.random.RandomState(3)
    p1 = jax.nn.softmax(jnp.asarray(rng.randn(V) * 1.5))
    q1 = jax.nn.softmax(jnp.asarray(rng.randn(V) * 1.5))
    p = jnp.tile(p1[None, None, :], (B, k, 1))
    q = jnp.tile(q1[None, None, :], (B, k, 1))
    seeds = jnp.arange(B, dtype=jnp.int32)
    offs = jnp.zeros((B,), jnp.int32)
    d0 = S.sample_rows(jnp.tile(q1[None, :], (B, 1)),
                       S.token_keys(seeds, offs, S.DRAFT))
    d = jnp.stack([d0, jnp.zeros_like(d0)], axis=1)
    toks, n_commit = S.grade_and_correct(
        p, q, d, jnp.ones((B,), bool), jnp.zeros((B, k), jnp.int32),
        seeds, offs)
    toks, n_commit = np.asarray(toks), np.asarray(n_commit)
    target = np.asarray(p1)
    # graded position: empirical frequency vs p
    freq0 = np.bincount(toks[:, 0], minlength=V).astype(float)
    assert 0.5 * np.abs(freq0 / B - target).sum() < 0.05
    # acceptance rate matches sum(min(p, q)) — the textbook rate
    want_acc = float(np.minimum(target, np.asarray(q1)).sum())
    assert abs((n_commit - 1).mean() - want_acc) < 0.05
    # bonus position (rows whose graded draft was accepted): also ~ p
    bonus = toks[n_commit == 2, 1]
    freq1 = np.bincount(bonus, minlength=V).astype(float)
    assert 0.5 * np.abs(freq1 / len(bonus) - target).sum() < 0.08
    # deterministic: the same inputs reproduce bitwise
    toks2, n2 = S.grade_and_correct(
        p, q, d, jnp.ones((B,), bool), jnp.zeros((B, k), jnp.int32),
        seeds, offs)
    assert np.array_equal(toks, np.asarray(toks2))
    assert np.array_equal(n_commit, np.asarray(n2))


def test_grade_and_correct_accepts_everything_when_q_equals_p():
    """q == p makes the accept probability min(1, p/q) = 1 — every round
    commits its full k (and the empty-residual fallback never has to
    invent mass)."""
    B, k, V = 256, 4, 8
    p1 = jax.nn.softmax(jnp.asarray(np.random.RandomState(0).randn(V)))
    p = jnp.tile(p1[None, None, :], (B, k, 1))
    seeds = jnp.arange(B, dtype=jnp.int32)
    offs = jnp.zeros((B,), jnp.int32)
    idx = jnp.repeat(seeds, k)
    pos = jnp.tile(jnp.arange(k), (B,))
    d = S.sample_rows(p.reshape(B * k, V),
                      S.token_keys(idx, pos, S.DRAFT)).reshape(B, k)
    _, n_commit = S.grade_and_correct(
        p, p, d, jnp.ones((B,), bool), jnp.zeros((B, k), jnp.int32),
        seeds, offs)
    assert int(np.asarray(n_commit).min()) == k


def test_filtered_probs_nucleus_and_greedy_rows():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]] * 3)
    temps = jnp.asarray([1.0, 1.0, 0.0])
    top_ps = jnp.asarray([1.0, 0.6, 0.5])
    p = np.asarray(S.filtered_probs(logits, temps, top_ps))
    full = np.exp([0, 1, 2, 3]) / np.exp([0, 1, 2, 3]).sum()
    assert np.allclose(p[0], full, atol=1e-6)          # top_p=1: softmax
    assert p[1][3] > 0 and p[1][0] == p[1][1] == 0     # nucleus drops tail
    assert np.isclose(p[1].sum(), 1.0, atol=1e-6)      # renormalized
    assert np.array_equal(p[2], [0, 0, 0, 1])          # greedy row: onehot


# ---------------------------------------------------------------------------
# Engine-level: statistical equivalence + greedy regression
# ---------------------------------------------------------------------------


def _streams(eng, n_calls=4, batch=8, max_new=8):
    """n_calls * batch independent sampled streams of one prompt, with
    disjoint seeds per stream."""
    prompt = _prompts([6], seed=2)[0]
    out = []
    for c in range(n_calls):
        samps = [SamplingParams(temperature=0.9, top_p=0.95,
                                seed=c * batch + i) for i in range(batch)]
        out += eng.generate([prompt] * batch, max_new_tokens=max_new,
                            sampling=samps)
    return out


@pytest.fixture(scope="module")
def sampled_streams(params):
    e4 = _engine(params, 4, max_batch=8)
    e1 = _engine(params, 1, max_batch=8)
    return _streams(e4), _streams(e1)


def test_spec_sampling_matches_serial_distribution(sampled_streams):
    """The statistical-equivalence gate: spec_k=4 rejection-sampling
    streams and non-speculative (k=1) cloud-sampling streams of the
    same prompt/temperature are draws from the same process.  Output
    index 0 is bitwise (both sides draw it from the CLOUD stream);
    later indices are pooled into an empirical marginal whose TV
    distance must be small — and far smaller than the distance to the
    greedy point mass (the power check)."""
    s4, s1 = sampled_streams
    assert [s[0] for s in s4] == [s[0] for s in s1]    # index 0: bitwise
    pool4 = np.bincount(np.concatenate([s[1:] for s in s4]),
                        minlength=CFG.vocab).astype(float)
    pool1 = np.bincount(np.concatenate([s[1:] for s in s1]),
                        minlength=CFG.vocab).astype(float)
    tv = _tv(pool4, pool1)
    assert tv < 0.30, tv
    # power: the same statistic separates sampling from greedy decode
    greedy = np.zeros(CFG.vocab)
    greedy[np.argmax(pool1)] = pool1.sum()
    assert _tv(pool4, greedy) > 0.45


def test_sampled_streams_deterministic_and_seed_sensitive(params):
    e_a = _engine(params, 4)
    e_b = _engine(params, 4)
    prompts = _prompts((6, 9), seed=4)
    got_a = e_a.generate(prompts, max_new_tokens=8, sampling=SP)
    got_b = e_b.generate(prompts, max_new_tokens=8, sampling=SP)
    assert got_a == got_b                      # fresh engine, same seeds
    other = e_b.generate(prompts, max_new_tokens=8,
                         sampling=SamplingParams(temperature=0.8,
                                                 top_p=0.9, seed=12))
    assert other != got_a                      # seed moves the stream


def test_temperature0_is_bitwise_greedy_and_never_traces_sampling(params):
    """The regression gate: ``sampling=None``, ``temperature=0``, and
    the pre-PR call signature all commit the identical stream, and
    greedy traffic never builds (traces) any sampled phase."""
    prompts = _prompts((7, 9, 8), seed=5)
    eng = _engine(params, 4)
    pre = eng.generate(prompts, max_new_tokens=6)
    none = eng.generate(prompts, max_new_tokens=6, sampling=None)
    t0 = eng.generate(prompts, max_new_tokens=6,
                      sampling=SamplingParams(temperature=0.0, seed=99))
    assert pre == none == t0
    assert not eng._samp_jits
    assert not getattr(eng, "_spec_sample_jits", {})


def test_mixed_batch_greedy_rows_stay_bitwise(params):
    """Greedy requests co-batched with sampled ones ride the sampled
    phases' argmax branch — in lossless mode their streams must equal
    the all-greedy run bit for bit."""
    prompts = _prompts((7, 9, 8, 6), seed=6)
    eng = _engine(params, 4)
    ref = eng.generate(prompts, max_new_tokens=6)
    mixed = eng.generate(
        prompts, max_new_tokens=6,
        sampling=[None, SP, SamplingParams(temperature=0.0), SP])
    assert mixed[0] == ref[0] and mixed[2] == ref[2]
    assert mixed[1] != ref[1]                  # the sampled rows did sample


# ---------------------------------------------------------------------------
# Replay determinism: preemption, fleet co-batching, chaos
# ---------------------------------------------------------------------------


def test_preemption_replay_keeps_sampled_stream_bit_identical(params):
    """Preempt-and-resume replays the committed prefix and re-enters the
    round loop at the same absolute output index — the (seed, index,
    stream) keys make the resumed sampled stream bitwise equal to the
    never-preempted run."""
    prompts = _prompts((6, 7, 9), seed=7)
    ref = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=4,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     page_size=PAGE, max_batch=4,
                                     max_len=64, **LOSSLESS)
    want = ref.generate(prompts, max_new_tokens=10, sampling=SP)
    dut = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=4,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     page_size=PAGE, max_batch=4,
                                     max_len=64, demand_paged=True,
                                     pressure=PressureSchedule(
                                         [(0.02, 0.3, 1)]), **LOSSLESS)
    got = dut.generate(prompts, max_new_tokens=10, sampling=SP)
    assert dut.stats.preemptions >= 1
    assert got == want


def test_fleet_cobatching_keeps_sampled_stream_bit_identical(params):
    """A sampled tenant's fleet stream equals the same requests served
    alone — co-batched greedy neighbours, shared pool, and group-masked
    rounds never perturb the per-request key streams."""
    prompts = _prompts((6, 9), seed=8)
    solo = _engine(params, 4)
    want = solo.generate(prompts, max_new_tokens=8, sampling=SP)
    fleet = FleetServingEngine(
        params, CFG, [TenantSpec("a", cut_layer=1, spec_k=4),
                      TenantSpec("b", cut_layer=1, spec_k=4)],
        max_batch=4, max_len=64, page_size=PAGE, **LOSSLESS)
    got = fleet.generate({"a": prompts, "b": _prompts([7], seed=9)},
                         max_new_tokens=8, sampling={"a": SP, "b": None})
    assert got["a"] == want


def test_chaos_outage_sampled_run_completes_and_degrades(params):
    """Under corruption + a cloud outage, sampled serving degrades to
    edge-only (drafter suffix, CLOUD-stream draws), resyncs, and still
    fills every budget — the stochastic twin of the INT8 chaos test."""
    fch = FaultyChannel(BASE_CH, seed=9, corrupt_p=0.2,
                        outages=[(0.05, 0.35)])
    eng = ResilientCollaborativeEngine(
        params, CFG, cut_layer=1, spec_k=2, channel=fch,
        transport=ReliableTransport(fch, max_retries=1,
                                    fallback_deadline_s=0.1),
        page_size=PAGE, max_batch=2, max_len=64)
    out = eng.generate(_prompts((9, 7, 8), seed=8), max_new_tokens=16,
                       sampling=SP)
    assert all(len(o) == 16 for o in out)
    assert eng.stats.edge_only_tokens > 0
    # it came back at least once (the q-heavier sampled wire shifts the
    # fault clock, so the *final* link state is timing-dependent)
    assert eng.stats.resyncs >= 1


# ---------------------------------------------------------------------------
# Wire + cost model price the q-row uplink consistently
# ---------------------------------------------------------------------------


def test_engine_charges_q_rows_on_sampled_spec_rounds(params):
    """Every sampled spec round ships the k-1 graded positions' f32
    draft distributions; with one live sampled slot the decode uplink is
    exactly rounds * (k-row blob + drafts + q rows + framing)."""
    eng = _engine(params, 4, max_batch=1)
    eng.generate(_prompts([6], seed=10), max_new_tokens=9, sampling=SP)
    k, D, V = 4, CFG.d_model, CFG.vocab
    per_round = (k * (D * 4 + _QP_BYTES) + (k - 1) * _TOK_BYTES
                 + (k - 1) * V * 4 + _MSG_BYTES)
    assert eng.stats.spec_rounds >= 2
    assert eng.stats.decode_bytes == eng.stats.spec_rounds * per_round


def test_costmodel_prices_q_bytes(params):
    """``speculative_round_time(draft_q_bytes=...)`` adds exactly
    (k-1) * q_bytes of uplink; ``lm_round_args(sampled_frac=...)``
    derives q_bytes from the vocab; and a pricier sampled uplink never
    makes the tuner draft *longer*."""
    ch = Channel.from_kbps(200, rtt_ms=20)
    kw = dict(edge_flops=1e7, cloud_flops=5e7, draft_flops=5e7,
              blob_bytes=128.0, edge=EDGE_TX2_CLASS,
              cloud=CLOUD_TITANXP_CLASS, channel=ch, acceptance=0.7,
              rows=1)
    k = 4
    t0 = speculative_round_time(k=k, **kw)
    qb = CFG.vocab * 4.0
    t1 = speculative_round_time(k=k, draft_q_bytes=qb, **kw)
    assert t1.channel_s - t0.channel_s == pytest.approx(
        (k - 1) * qb / ch.bandwidth_bytes_per_s, rel=1e-6)
    assert t1.decode_s == t0.decode_s and t1.tokens == t0.tokens
    args = lm_round_args(CFG, 1, batch=2, sampled_frac=0.5)
    assert args["draft_q_bytes"] == pytest.approx(0.5 * 2 * CFG.vocab * 4.0)
    best_greedy, _ = tune_spec_k(ks=(1, 2, 4, 8), **kw)
    best_sampled, _ = tune_spec_k(ks=(1, 2, 4, 8),
                                  draft_q_bytes=50 * qb, **kw)
    assert best_sampled.k <= best_greedy.k


# ---------------------------------------------------------------------------
# Telemetry: zero-acceptance rounds are first-class samples
# ---------------------------------------------------------------------------


def test_observe_round_zero_acceptance_is_a_sample():
    """An all-rejected round (routine at high temperature) must SET the
    acceptance estimate to 0.0, not be dropped on the floor — otherwise
    ``tune_spec_k`` keeps drafting at the optimistic prior forever."""
    tl = LinkTelemetry()
    assert tl.acceptance(prior=0.8) == 0.8     # no evidence: the prior
    tl.observe_round(4, 0)
    assert tl.acceptance(prior=0.8) == 0.0     # first sample, not prior
    for _ in range(20):
        tl.observe_round(4, 0)
    assert tl.acceptance() == 0.0              # EWMA stays pinned at 0


def test_observe_round_skips_ungraded_and_clamps():
    tl = LinkTelemetry()
    tl.observe_round(0, 0)                     # k=1 round: no evidence
    assert tl.acceptance(prior=0.8) == 0.8 and tl.n_rounds == 0
    tl.observe_round(4, 9)                     # defensive clamp to [0, 1]
    assert tl.acceptance() == 1.0
    tl2 = LinkTelemetry()
    tl2.observe_round(4, -3)
    assert tl2.acceptance() == 0.0
