"""Elastic-scaling integration: train on an 8-device mesh, lose devices,
re-plan a 4-device mesh, restore the checkpoint RESHARDED onto it, and
continue — loss trajectory must continue from where it stopped.

Runs in a subprocess (8 forced host devices)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.distributed.checkpoint import restore_checkpoint, \\
        save_checkpoint
    from repro.distributed.ft import plan_elastic_mesh
    from repro.models import layers as L
    from repro.train.optim import AdamWConfig, adamw_init, adamw_update

    from repro.launch.mesh import _mk_mesh

    def make_mesh(data, model):
        return _mk_mesh((data, model), ("data", "model"),
                        devices=jax.devices()[: data * model])

    key = jax.random.PRNGKey(0)
    params = {"l1": L.dense_init(key, 16, 32),
              "l2": L.dense_init(jax.random.fold_in(key, 1), 32, 4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    rngd = np.random.RandomState(0)
    X = jnp.asarray(rngd.randn(64, 16).astype(np.float32))
    Y = jnp.asarray(rngd.randint(0, 4, 64).astype(np.int32))

    def loss_fn(p):
        h = L.dense(p["l1"], X, act="relu", name="l1")
        logits = L.dense(p["l2"], h, name="l2")
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, Y[:, None], -1)[:, 0]
        return jnp.mean(logz - gold)

    def shardings(mesh):
        def per_leaf(l):
            if l.ndim == 2 and l.shape[0] % mesh.shape["data"] == 0 \\
                    and l.shape[1] % mesh.shape["model"] == 0:
                return NamedSharding(mesh, P("data", "model"))
            return NamedSharding(mesh, P())
        return jax.tree_util.tree_map(per_leaf, params)

    def place(tree, sh):
        return jax.tree_util.tree_map(
            lambda l, s: jax.device_put(jnp.asarray(l), s), tree, sh)

    # --- phase 1: 4x2 mesh, 5 steps, checkpoint -------------------------
    mesh8 = make_mesh(4, 2)
    sh8 = shardings(mesh8)
    p = place(params, sh8)
    step = jax.jit(lambda p, o: (lambda l, g: adamw_update(g, o, p, cfg))(
        *jax.value_and_grad(loss_fn)(p)))
    o = opt
    losses = []
    for i in range(5):
        losses.append(float(loss_fn(p)))
        p, o, _ = step(p, o)
    save_checkpoint("/tmp/elastic_ck", 5, {"p": p, "o": o})

    # --- phase 2: "lose" 4 devices; re-plan; restore resharded ----------
    data, model = plan_elastic_mesh(4, model_parallel=2)
    assert (data, model) == (2, 2)
    mesh4 = make_mesh(data, model)
    sh4 = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh4, P()), {"p": p, "o": o})
    state, restored_step, _ = restore_checkpoint(
        "/tmp/elastic_ck", {"p": p, "o": o}, shardings=sh4)
    assert restored_step == 5
    p2 = state["p"]
    # every leaf now lives on the 4-device mesh
    for leaf in jax.tree_util.tree_leaves(p2):
        assert set(leaf.devices()) <= set(mesh4.devices.flatten())

    # --- phase 3: continue training; loss keeps falling -----------------
    o2 = state["o"]
    for i in range(5):
        losses.append(float(loss_fn(p2)))
        p2, o2, _ = step(p2, o2)
    assert losses[-1] < losses[5] < losses[0], losses
    # continuity: restored loss equals pre-failure loss
    assert abs(float(loss_fn(p2)) - losses[-1]) < 1.0
    print("ELASTIC_OK", [round(l, 3) for l in losses])
""")


@pytest.mark.slow
def test_elastic_shrink_and_resume():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=420, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert "ELASTIC_OK" in proc.stdout, (
        proc.stdout[-1500:], proc.stderr[-2500:])
