"""Paged flash-decode attention + block-table page allocator.

Covers: Pallas kernel (interpret) vs XLA oracle parity, paged INT8-KV
decode tracking dense fp greedy tokens on a tiny LM, allocator
invariants (no double allocation, reclamation on retire, block-table
bounds), and the bucketed-prefill compile bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (paged_attention_ref,
                                           paged_flash_decode)
from repro.models import transformer as TF
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (CollaborativeServingEngine, PageAllocator,
                                ServingEngine, _bucket_len)

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="paged-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(n, plen=6, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, plen).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------


def _rand_paged(seed, *, b=3, n_heads=8, n_kv=4, hd=16, page=8, n_pages=14,
                pages_per=4, int8=True):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, n_heads, hd).astype(np.float32))
    if int8:
        kp = jnp.asarray(
            rng.randint(-127, 128, (n_pages, page, n_kv, hd)).astype(np.int8))
        vp = jnp.asarray(
            rng.randint(-127, 128, (n_pages, page, n_kv, hd)).astype(np.int8))
        ks = jnp.asarray(rng.uniform(0.01, 0.05, (b, n_kv)).astype(np.float32))
        vs = jnp.asarray(rng.uniform(0.01, 0.05, (b, n_kv)).astype(np.float32))
    else:
        kp = jnp.asarray(rng.randn(n_pages, page, n_kv, hd).astype(np.float32))
        vp = jnp.asarray(rng.randn(n_pages, page, n_kv, hd).astype(np.float32))
        ks = vs = None
    # each row gets its own permutation of physical pages (never page 0)
    bt = jnp.asarray(np.stack([
        rng.choice(np.arange(1, n_pages), pages_per, replace=False)
        for _ in range(b)]).astype(np.int32))
    lens = jnp.asarray(rng.randint(1, pages_per * page + 1, b), jnp.int32)
    return q, kp, vp, bt, lens, ks, vs


@pytest.mark.parametrize("int8", [True, False])
def test_kernel_matches_ref(int8):
    """Pallas online-softmax over block-table pages == gather oracle."""
    q, kp, vp, bt, lens, ks, vs = _rand_paged(0, int8=int8)
    ref = paged_attention_ref(q, kp, vp, bt, lens, ks, vs)
    out = paged_flash_decode(q, kp, vp, bt, lens, ks, vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_respects_lengths_and_table():
    """Entries past each row's length — and pages not in its table row —
    must not influence the output."""
    q, kp, vp, bt, lens, ks, vs = _rand_paged(1)
    ref = paged_attention_ref(q, kp, vp, bt, lens, ks, vs)
    # poison everything outside the valid region of row 0's pages
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    flat_pages = set(np.asarray(bt).reshape(-1).tolist())
    for pg in range(kp2.shape[0]):
        if pg not in flat_pages:
            kp2[pg] = 127
            vp2[pg] = 127
    out = paged_flash_decode(q, jnp.asarray(kp2), jnp.asarray(vp2), bt,
                             lens, ks, vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ref_matches_dense_sdpa():
    """Gathering the pages back into a dense cache and running the
    reference einsum softmax reproduces the paged oracle (fp path)."""
    from repro.models.layers import _sdpa

    q, kp, vp, bt, lens, _, _ = _rand_paged(2, int8=False, b=2, n_heads=4,
                                            n_kv=2)
    ref = paged_attention_ref(q, kp, vp, bt, lens)
    b, n_heads, hd = q.shape
    span = bt.shape[1] * kp.shape[1]
    k = kp[bt].reshape(b, span, 2, hd)
    v = vp[bt].reshape(b, span, 2, hd)
    k = jnp.repeat(k, 2, axis=2)
    v = jnp.repeat(v, 2, axis=2)
    dense = _sdpa(q[:, None], k, v, causal=True, q_offset=lens - 1)[:, 0]
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_through_model_stack(params):
    """Force the Pallas kernel (interpret) through attention/run_blocks
    and compare against the default XLA-ref dispatch."""
    from repro.kernels import paged_attention as PA

    prompts = _prompts(2, plen=7, seed=3)
    ref_eng = ServingEngine(params, CFG, max_batch=2, max_len=32,
                            paged=True, page_size=8)
    ref_out = ref_eng.generate(prompts, max_new_tokens=4)
    old = PA._DEFAULT_IMPL
    PA._DEFAULT_IMPL = "pallas_interpret"
    try:
        pal_eng = ServingEngine(params, CFG, max_batch=2, max_len=32,
                                paged=True, page_size=8)
        pal_out = pal_eng.generate(prompts, max_new_tokens=4)
    finally:
        PA._DEFAULT_IMPL = old
    assert pal_out == ref_out


# ---------------------------------------------------------------------------
# Paged / INT8 engines vs dense fp greedy
# ---------------------------------------------------------------------------


def test_paged_fp_engine_matches_dense_engine(params):
    """fp page pool is a pure layout change — greedy tokens match the
    dense engine's."""
    prompts = _prompts(3, plen=6, seed=1)
    dense = ServingEngine(params, CFG, max_batch=3, max_len=32)
    paged = ServingEngine(params, CFG, max_batch=3, max_len=32, paged=True,
                          page_size=8)
    assert paged.generate(prompts, max_new_tokens=6) == \
        dense.generate(prompts, max_new_tokens=6)


def test_paged_int8_engine_tracks_dense_fp(params):
    """INT8 pages + per-slot prefill-calibrated scales reproduce dense
    fp greedy tokens within quant tolerance on the tiny LM."""
    prompts = _prompts(4, plen=8, seed=5)
    dense = ServingEngine(params, CFG, max_batch=4, max_len=32)
    q8 = ServingEngine(params, CFG, max_batch=4, max_len=32, paged=True,
                       page_size=8, int8_kv=True)
    ref = dense.generate(prompts, max_new_tokens=6)
    got = q8.generate(prompts, max_new_tokens=6)
    assert q8._cache["k_pages"].dtype == jnp.int8
    agree = sum(a == b for r, g in zip(ref, got) for a, b in zip(r, g))
    assert agree / sum(len(r) for r in ref) >= 0.6, (ref, got)
    # and the footprint really is ~1 B/elem on live pages only
    assert q8.cache_bytes(live_only=True) < dense.cache_bytes() / 3


def test_collab_default_quantized_edge_tracks_fp_edge(params):
    """The collaborative engine's default (paged INT8 edge cache with
    per-slot prefill calibration) stays within quant tolerance of the
    fp-edge-cache configuration."""
    prompts = _prompts(3, plen=6, seed=2)
    fp = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=3,
                                    max_len=32, edge_paged=False,
                                    edge_int8=False, cloud_paged=False,
                                    cloud_int8=False)
    q8 = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=3,
                                    max_len=32)
    assert q8.edge_paged and q8.edge_int8          # the default layout
    assert q8._edge_cache["k_pages"].dtype == jnp.int8
    ref = fp.generate(prompts, max_new_tokens=6)
    got = q8.generate(prompts, max_new_tokens=6)
    agree = sum(a == b for r, g in zip(ref, got) for a, b in zip(r, g))
    assert agree / sum(len(r) for r in ref) >= 0.6, (ref, got)


# ---------------------------------------------------------------------------
# Page allocator invariants
# ---------------------------------------------------------------------------


def test_allocator_no_double_allocation_and_reclaim():
    rng = np.random.RandomState(0)
    alloc = PageAllocator(64)
    held = {}
    for step in range(300):
        if held and (rng.rand() < 0.4 or alloc.num_free < 4):
            key = list(held)[rng.randint(len(held))]
            alloc.free(held.pop(key))
        else:
            n = int(rng.randint(1, 5))
            if n > alloc.num_free:
                continue
            pages = alloc.alloc(n)
            # bounds: physical ids stay inside the pool, never page 0
            assert all(1 <= p < 64 for p in pages)
            held[step] = pages
        # no page is ever held twice
        flat = [p for ps in held.values() for p in ps]
        assert len(flat) == len(set(flat))
        assert set(flat) == set(alloc.live)
        assert alloc.num_free == 63 - len(flat)
    for ps in held.values():
        alloc.free(ps)
    assert alloc.num_free == 63 and not alloc.live


def test_calibration_ignores_bucket_padding(params):
    """Per-slot INT8 scales calibrated from a bucket-padded prefill must
    equal the scales from the exact-length prompt: padding K/V (pad
    embeddings at tail RoPE phases) must not set a request's range."""
    import repro.models.layers as ML

    rng = np.random.RandomState(4)
    toks = rng.randint(1, CFG.vocab, (2, 9)).astype(np.int32)
    bt = jnp.asarray(np.array([[1, 2], [3, 4]], np.int32))

    def scales(tokens, last_pos):
        cache = TF.init_cache(CFG, 2, max_len=16, paged=True, page_size=8,
                              quantized=True, num_pages=5)
        _, c = TF.prefill(params, jnp.asarray(tokens), CFG, cache=cache,
                          block_tables=bt, last_pos=last_pos)
        return np.asarray(c["k_scale"]), np.asarray(c["v_scale"])

    exact_k, exact_v = scales(toks, jnp.full((2,), 8, jnp.int32))
    padded = np.zeros((2, 16), np.int32)
    padded[:, :9] = toks
    pad_k, pad_v = scales(padded, jnp.full((2,), 8, jnp.int32))
    np.testing.assert_allclose(pad_k, exact_k, rtol=1e-6)
    np.testing.assert_allclose(pad_v, exact_v, rtol=1e-6)


def test_undersized_pool_backpressures_admission(params):
    """A deliberately small page pool serializes admission instead of
    crashing: the second request waits for the first one's pages."""
    # each request needs 3 pages (6+12 tokens, page 8); pool has 4 usable
    eng = ServingEngine(params, CFG, max_batch=2, max_len=32, paged=True,
                        page_size=8, num_pages=5)
    ref = ServingEngine(params, CFG, max_batch=2, max_len=32)
    prompts = _prompts(2, plen=6, seed=9)
    got = eng.generate(prompts, max_new_tokens=12)
    assert got == ref.generate(prompts, max_new_tokens=12)
    assert eng.stats.prefill_calls == 2       # serialized, not batched
    assert eng._pool.allocator.num_free == 4  # fully reclaimed

    # a pool that can never hold even one max-length slot is a config
    # error, rejected at construction (intentional undersizing only
    # bounds concurrency, never feasibility)
    with pytest.raises(ValueError, match="page pool"):
        ServingEngine(params, CFG, max_batch=2, max_len=32, paged=True,
                      page_size=8, num_pages=2)


def test_allocator_exhaustion_and_double_free_raise():
    alloc = PageAllocator(4)
    pages = alloc.alloc(3)
    with pytest.raises(RuntimeError):
        alloc.alloc(1)
    alloc.free(pages[:1])
    with pytest.raises(ValueError):
        alloc.free(pages[:1])


def test_engine_returns_pages_on_retire(params):
    """More requests than slots: pages recycle through the free list and
    the pool is fully reclaimed after the run."""
    eng = ServingEngine(params, CFG, max_batch=2, max_len=32, paged=True,
                        page_size=8)
    pool = eng._pool.allocator
    n0 = pool.num_free
    outs = eng.generate(_prompts(5, plen=6, seed=7), max_new_tokens=4)
    assert len(outs) == 5 and all(len(o) == 4 for o in outs)
    assert pool.num_free == n0 and not pool.live
    assert np.all(eng._pool.bt == 0)


def test_paged_block_tables_stay_in_bounds(params):
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=2,
                                     max_len=32, page_size=8)
    eng.generate(_prompts(4, plen=9, seed=8), max_new_tokens=4)
    n_pages = eng._edge_cache["k_pages"].shape[1]
    assert int(eng._pool.bt.max()) < n_pages
    assert int(eng._pool.bt.min()) >= 0


# ---------------------------------------------------------------------------
# Bucketed prefill
# ---------------------------------------------------------------------------


def test_bucket_len():
    assert [_bucket_len(p, 64) for p in (1, 5, 8, 9, 16, 17, 40)] == \
        [8, 8, 8, 16, 16, 32, 64]
    assert _bucket_len(40, 48) == 48          # capped at max_len


def test_prefill_compiles_bounded_by_buckets(params):
    """Five distinct prompt lengths, two buckets → exactly two prefill
    traces (the seed engine retraced per unique length)."""
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, CFG.vocab, l).astype(np.int32)
               for l in (5, 6, 7, 9, 11)]
    eng = ServingEngine(params, CFG, max_batch=1, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=3)
    assert len(outs) == 5
    assert eng.stats.prefill_calls == 5
    assert eng.trace_counts["prefill"] == 2    # buckets {8, 16}
    assert eng.trace_counts["decode"] == 1


def test_bucketed_prefill_tokens_match_unbucketed(params):
    """Right-padding prompts to the bucket must not change greedy
    output: padded K/V beyond the true length are masked/overwritten."""
    from repro.models.transformer import forward

    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, CFG.vocab, l).astype(np.int32)
               for l in (5, 9, 13)]
    eng = ServingEngine(params, CFG, max_batch=3, max_len=32)
    for p, got in zip(prompts, eng.generate(prompts, max_new_tokens=4)):
        toks = list(p)
        for _ in range(4):
            logits, _ = forward(params, jnp.asarray([toks], jnp.int32), CFG)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert toks[len(p):] == got
