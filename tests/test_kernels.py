"""Pallas int8 matmul kernel vs pure-jnp oracle — shape/dtype sweeps.

Kernels run in interpret mode on CPU (the TPU is the compile target);
the integer accumulation path must match the oracle exactly and the
float epilogue to tight tolerance.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip, don't "
    "kill collection of the whole tier-1 suite")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.quant import QuantParams, compute_qparams, quantize
from repro.kernels.ops import int8_matmul, quantized_dense
from repro.kernels.ref import int8_matmul_ref, quantized_dense_ref

jax.config.update("jax_platform_name", "cpu")


def _mk_inputs(m, k, n, seed=0, per_channel=False):
    rng = np.random.RandomState(seed)
    a = jnp.asarray(rng.uniform(-4, 3, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(-0.8, 1.1, (k, n)).astype(np.float32))
    qa = compute_qparams(a)
    qw = compute_qparams(w, axis=1 if per_channel else None)
    return quantize(a, qa), quantize(w, qw), qa, qw


SHAPES = [
    (8, 16, 8),
    (16, 32, 24),       # non-multiple of blocks
    (128, 128, 128),
    (64, 256, 96),
    (1, 64, 40),        # single row (decode-like)
    (33, 65, 17),       # awkward primes
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("per_channel", [False, True])
def test_matmul_matches_ref_f32out(m, k, n, per_channel):
    a_q, b_q, qa, qw = _mk_inputs(m, k, n, seed=m + n, per_channel=per_channel)
    got = int8_matmul(a_q, b_q, qa, qw, interpret=True)
    want = int8_matmul_ref(a_q, b_q, qa, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu"])
def test_matmul_fused_activation(act):
    a_q, b_q, qa, qw = _mk_inputs(32, 64, 48, seed=7)
    bias = jnp.asarray(np.random.RandomState(8).randn(48).astype(np.float32))
    got = int8_matmul(a_q, b_q, qa, qw, bias=bias, act=act, interpret=True)
    want = int8_matmul_ref(a_q, b_q, qa, qw, bias=bias, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_matmul_requant_int8_out_exact():
    a_q, b_q, qa, qw = _mk_inputs(64, 128, 32, seed=3)
    ref_f32 = int8_matmul_ref(a_q, b_q, qa, qw, act="relu")
    out_qp = compute_qparams(ref_f32)
    got = int8_matmul(a_q, b_q, qa, qw, act="relu", out_qp=out_qp,
                      interpret=True)
    want = int8_matmul_ref(a_q, b_q, qa, qw, act="relu", out_qp=out_qp)
    assert got.dtype == jnp.int8
    # integer outputs must agree within 1 ulp (float epilogue rounding)
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01


def test_matmul_against_float_truth():
    """End-to-end: quantized path ≈ fp32 matmul within quantization noise."""
    m, k, n = 64, 256, 64
    rng = np.random.RandomState(11)
    a = jnp.asarray(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(-1, 1, (k, n)).astype(np.float32))
    qa, qw = compute_qparams(a), compute_qparams(w, axis=1)
    got = int8_matmul(quantize(a, qa), quantize(w, qw), qa, qw,
                      interpret=True)
    truth = a @ w
    rel = float(jnp.linalg.norm(got - truth) / jnp.linalg.norm(truth))
    assert rel < 0.01, rel


def test_quantized_dense_3d_batch():
    rng = np.random.RandomState(12)
    x = jnp.asarray(rng.randn(4, 9, 32).astype(np.float32))
    w = jnp.asarray(rng.randn(32, 24).astype(np.float32))
    qx, qw = compute_qparams(x), compute_qparams(w, axis=1)
    w_q = quantize(w, qw)
    got = quantized_dense(x, w_q, qx, qw, act="relu", interpret=True)
    want = quantized_dense_ref(x, w_q, qx, qw, act="relu")
    assert got.shape == (4, 9, 24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_blocked_grid_multiple_k_steps():
    """Force a multi-step K grid so the scratch accumulation path runs."""
    a_q, b_q, qa, qw = _mk_inputs(16, 512, 16, seed=5)
    got = int8_matmul(a_q, b_q, qa, qw, block=(16, 16, 128), interpret=True)
    want = int8_matmul_ref(a_q, b_q, qa, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 80), st.integers(1, 40),
       st.booleans())
def test_prop_any_shape_matches_ref(m, k, n, per_channel):
    a_q, b_q, qa, qw = _mk_inputs(m, k, n, seed=m * 89 + k * 7 + n,
                                  per_channel=per_channel)
    got = int8_matmul(a_q, b_q, qa, qw, interpret=True)
    want = int8_matmul_ref(a_q, b_q, qa, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
