"""Partition-rule tests — reproduce the paper's Table 1 & 2 analysis."""
import pytest

from repro.core.graph import LayerGraph
from repro.core.partition import (
    candidate_partition_points,
    merge_non_parametric,
    partition_report,
)


def inception_graph() -> LayerGraph:
    """GoogLeNet-style inception module (paper Fig. 2a).

    Topo order enters the branch under test (branch2) first, matching the
    paper's "brother branch runs in the cloud" accounting.
    """
    g = LayerGraph("inception")
    g.add("input", "input", [], (1, 3, 32, 32))
    g.add("pre", "conv", ["input"], (1, 64, 32, 32), flops=1e6, param_elems=1728)
    # branch 2 (1x1 -> 3x3) — the branch under test
    g.add("b2a", "conv", ["pre"], (1, 32, 32, 32), flops=1e6, param_elems=2048)
    g.add("b2a_relu", "relu", ["b2a"], (1, 32, 32, 32))
    g.add("b2b", "conv", ["b2a_relu"], (1, 64, 32, 32), flops=2e6,
          param_elems=18432)
    # branch 1 (1x1)
    g.add("b1", "conv", ["pre"], (1, 64, 32, 32), flops=1e6, param_elems=4096)
    # branch 3 (1x1 -> 5x5)
    g.add("b3a", "conv", ["pre"], (1, 16, 32, 32), flops=5e5, param_elems=1024)
    g.add("b3b", "conv", ["b3a"], (1, 32, 32, 32), flops=2e6, param_elems=12800)
    # branch 4 (pool -> 1x1)
    g.add("b4p", "maxpool", ["pre"], (1, 64, 32, 32))
    g.add("b4b", "conv", ["b4p"], (1, 32, 32, 32), flops=1e6, param_elems=2048)
    g.add("concat", "concat", ["b1", "b2b", "b3b", "b4b"], (1, 192, 32, 32))
    g.add("post", "conv", ["concat"], (1, 64, 32, 32), flops=3e6,
          param_elems=12288)
    g.validate()
    return g


def residual_graph() -> LayerGraph:
    """Residual block with identity shortcut (paper Fig. 2b)."""
    g = LayerGraph("residual")
    g.add("input", "input", [], (1, 64, 16, 16))
    g.add("pre", "conv", ["input"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)                          # paper point 1
    g.add("conv_a", "conv", ["pre"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)                          # spanned by shortcut
    g.add("relu_a", "relu", ["conv_a"], (1, 64, 16, 16))
    g.add("conv_b", "conv", ["relu_a"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)                          # spanned by shortcut
    g.add("add", "add", ["conv_b", "pre"], (1, 64, 16, 16))
    g.add("relu_out", "relu", ["add"], (1, 64, 16, 16))
    g.add("post", "conv", ["relu_out"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)                          # paper point 5
    g.validate()
    return g


# -------------------------- Table 1 (inception) ---------------------------

def test_table1_no_brother_points_single_int8_blob():
    g = inception_graph()
    for point in ("pre",):                         # paper's point 1
        blobs = g.crossing_blobs(point)
        assert len(blobs) == 1 and blobs[0].precision == "int8"
    merged = merge_non_parametric(g)
    # paper's point 13 == the concat output; the concat fuses into the
    # topo-latest branch conv (b4b), whose cut ships exactly 1 INT8 blob.
    host = [n for n in merged.topo() if "concat" in merged[n].fused]
    assert host == ["b4b"]
    blobs = merged.crossing_blobs("b4b")
    assert len(blobs) == 1 and blobs[0].precision == "int8"


def test_table1_brother_on_cloud_int8_plus_fp32():
    """Cut inside branch 2 with brothers uncomputed → 1×INT8 + 1×FP32."""
    g = inception_graph()
    for point in ("b2a", "b2b"):
        blobs = g.crossing_blobs(point)
        kinds = sorted(b.precision for b in blobs)
        assert kinds == ["fp32", "int8"][::-1] or kinds == ["fp32", "int8"], blobs
        assert len(blobs) == 2
        assert {b.source for b in blobs} == {point, "pre"}


def test_table1_brother_on_edge_four_blobs():
    """All four branches computed on edge → 4 blobs cross (paper 4×INT8)."""
    g = inception_graph()
    blobs = g.crossing_blobs("b4b")      # last branch; others complete
    assert len(blobs) == 4
    assert {b.source for b in blobs} == {"b1", "b2b", "b3b", "b4b"}


def test_inception_candidates_exclude_branch_interiors():
    g = inception_graph()
    cands = {c.name for c in candidate_partition_points(g)}
    assert "pre" in cands
    assert "b4b" in cands               # the fused concat point (paper pt 13)
    assert "post" in cands
    for interior in ("b2a", "b2b", "b1", "b3a", "b3b"):
        assert interior not in cands


# -------------------------- Table 2 (residual) -----------------------------

def test_table2_no_shortcut_points_single_int8_blob():
    g = residual_graph()
    blobs = g.crossing_blobs("pre")                 # point 1
    assert len(blobs) == 1 and blobs[0].precision == "int8"
    merged = merge_non_parametric(g)
    # point 5 = after the residual add (add fuses into conv_b)
    assert "add" in merged["conv_b"].fused
    blobs = merged.crossing_blobs("conv_b")
    assert len(blobs) == 1 and blobs[0].precision == "int8"


def test_table2_shortcut_spanned_int8_plus_fp32():
    g = residual_graph()
    for point in ("conv_a", "conv_b"):
        blobs = g.crossing_blobs(point)
        assert len(blobs) == 2
        precisions = {b.source: b.precision for b in blobs}
        assert precisions[point] == "int8"
        assert precisions["pre"] == "fp32"          # the live shortcut


def test_residual_candidates():
    g = residual_graph()
    cands = {c.name for c in candidate_partition_points(g)}
    assert cands == {"input", "pre", "conv_b", "post"}
    # conv_b is point 5 (the fused add); conv_a (spanned) is excluded.


# ----------------------- rule 1: non-parametric merge ----------------------

def test_merge_absorbs_relu_and_pool_costs():
    g = LayerGraph("chain")
    g.add("input", "input", [], (1, 8))
    g.add("fc", "dense", ["input"], (1, 16), flops=256, param_elems=128)
    g.add("relu", "relu", ["fc"], (1, 16), flops=16)
    g.add("pool", "avgpool", ["relu"], (1, 4), flops=16)
    m = merge_non_parametric(g)
    assert list(m.topo()) == ["input", "fc"]
    assert m["fc"].fused == ["relu", "pool"]
    assert m["fc"].flops == 256 + 16 + 16
    assert m["fc"].out_shape == (1, 4)              # fused output shape


def test_candidates_monotone_edge_flops():
    g = inception_graph()
    cands = candidate_partition_points(g)
    flops = [c.edge_flops for c in cands]
    assert flops == sorted(flops)


def test_multi_stream_max_blobs_extension():
    """Two parallel residual streams (MMDiT-style): no single-blob interior
    cut exists; max_blobs=2 recovers the block boundaries."""
    g = LayerGraph("dual")
    g.add("input", "input", [], (1, 8))
    g.add("img0", "dense", ["input"], (1, 8), flops=64, param_elems=64)
    g.add("txt0", "dense", ["input"], (1, 8), flops=64, param_elems=64)
    g.add("img1", "dense", ["img0", "txt0"], (1, 8), flops=64, param_elems=64)
    g.add("txt1", "dense", ["txt0", "img0"], (1, 8), flops=64, param_elems=64)
    g.add("img2", "dense", ["img1", "txt1"], (1, 8), flops=64, param_elems=64)
    g.add("txt2", "dense", ["txt1", "img1"], (1, 8), flops=64, param_elems=64)
    g.add("head", "dense", ["img2", "txt2"], (1, 8), flops=64, param_elems=64)
    single = candidate_partition_points(g, include_input=False,
                                        include_last=False)
    assert [c.name for c in single] == []
    dual = candidate_partition_points(g, max_blobs=2, include_input=False,
                                      include_last=False)
    # txt1/txt2 are the stream-pair block boundaries; img0/txt0 are also
    # legitimate 2-blob cuts near the input (they ship {own, input} and
    # {own, sibling}); img1/img2 cross 3 blobs and stay excluded.
    assert {c.name for c in dual} == {"img0", "txt0", "txt1", "txt2"}
    assert all(c.n_blobs <= 2 for c in dual)


def test_partition_report_runs():
    rep = partition_report(inception_graph())
    assert "candidates" in rep and "pre" in rep
